//! The candidate enumerator and budget-bounded search.
//!
//! The search space is exactly the existing plan space — every
//! candidate is a [`PlanSpec`] (strategy × algorithm) or an
//! overlap-save block length the serving planes could already be
//! asked for explicitly.  Tuning therefore cannot change any result
//! bit: it only reorders which of the already-verified plans `Auto`
//! requests land on.
//!
//! The budget is a soft wall-clock bound checked *between*
//! measurements: the first key of the sweep always completes (so even
//! a tiny CI budget produces usable wisdom), and once the budget is
//! exhausted the remaining keys are skipped and reported as such
//! rather than half-measured.

use std::time::{Duration, Instant};

use crate::fft::{Algorithm, DType, FftResult, PlanSpec, Strategy};
use crate::kernel::Kernel;
use crate::stream::min_ols_block;

use super::measure::{measure_fft, measure_ols, MeasureConfig};
use super::wisdom::{TuneOp, Wisdom, WisdomEntry};

/// What to sweep and how long to spend.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// FFT sizes to tune (each × every dtype in `dtypes`).
    pub sizes: Vec<usize>,
    /// Overlap-save tap counts to tune block lengths for.
    pub taps: Vec<usize>,
    /// Dtypes to tune.
    pub dtypes: Vec<DType>,
    /// Soft wall-clock budget for the whole sweep.
    pub budget: Duration,
    /// Repetition policy per candidate.
    pub measure: MeasureConfig,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            sizes: vec![256, 1024, 4096],
            taps: vec![32],
            dtypes: vec![DType::F32],
            budget: Duration::from_secs(2),
            measure: MeasureConfig::default(),
        }
    }
}

/// One winner row for reports (`fmafft tune` table, `BENCH_tune.json`).
#[derive(Clone, Debug)]
pub struct TuneRow {
    pub op: TuneOp,
    pub n: usize,
    pub dtype: DType,
    pub strategy: Strategy,
    pub algorithm: Algorithm,
    pub kernel: Kernel,
    pub block_len: usize,
    pub median_ns: u64,
    /// How many candidates were actually measured for this key.
    pub candidates: usize,
}

/// The completed search: validated wisdom plus the report rows.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub wisdom: Wisdom,
    pub rows: Vec<TuneRow>,
    /// True when the budget ran out before the sweep finished.
    pub budget_exhausted: bool,
}

/// Every (strategy, algorithm, kernel) plan candidate for an
/// `n`-point FFT in `dtype`.  Fixed-point planes only represent the
/// dual-select tables over the Stockham kernel; float planes sweep
/// all four strategies over Stockham r2, r4 (power-of-four sizes,
/// ratio strategies only — the r4 kernel has no standard-butterfly
/// form), DIT and Bluestein, all with `Kernel::Auto` (the kernel axis
/// is meaningless to them).  Sizes the mixed-radix engine serves
/// (`2^a·3^b`, ratio strategies) additionally sweep
/// `Algorithm::MixedRadix` per kernel arm: the scalar arm everywhere,
/// the SIMD arm for hardware floats (it may still fail to *build* on
/// a host without AVX2+FMA, in which case the sweep skips it like any
/// other unbuildable candidate).  Candidates the planner would
/// statically reject (e.g. r4 × standard) are kept out here so the
/// measured count matches the true space.
pub fn fft_candidates(n: usize, dtype: DType) -> Vec<(Strategy, Algorithm, Kernel)> {
    if dtype.is_fixed() {
        return vec![(Strategy::DualSelect, Algorithm::Stockham, Kernel::Auto)];
    }
    let pow4 = n.is_power_of_two() && n.trailing_zeros() % 2 == 0;
    let smooth = crate::kernel::is_23_smooth(n);
    let hw_float = matches!(dtype, DType::F64 | DType::F32);
    let mut out = Vec::new();
    for s in Strategy::ALL {
        if n.is_power_of_two() && n >= 2 {
            out.push((s, Algorithm::Stockham, Kernel::Auto));
            out.push((s, Algorithm::Dit, Kernel::Auto));
            if pow4 && s != Strategy::Standard {
                out.push((s, Algorithm::Radix4, Kernel::Auto));
            }
        }
        out.push((s, Algorithm::Bluestein, Kernel::Auto));
        if smooth && s != Strategy::Standard {
            out.push((s, Algorithm::MixedRadix, Kernel::Scalar));
            if hw_float {
                out.push((s, Algorithm::MixedRadix, Kernel::Simd));
            }
        }
    }
    out
}

/// Every overlap-save FFT block-length candidate for an `L`-tap
/// filter: powers of two from the feasibility floor 2L−1 rounded up
/// (the smallest block holding a full overlap plus one valid output
/// sample) through 16L (past which per-sample FFT cost has flattened
/// for every size this crate serves).
pub fn ols_block_candidates(taps: usize) -> Vec<usize> {
    let floor = min_ols_block(taps);
    let ceil = (16 * taps.max(1)).next_power_of_two();
    let mut out = Vec::new();
    let mut b = floor;
    while b <= ceil.max(floor) {
        out.push(b);
        b *= 2;
    }
    out
}

/// Run the sweep described by `cfg`.  Unbuildable candidates are
/// skipped; a key where *no* candidate builds (there are none in the
/// shipped plan space) simply produces no entry.  Measurement errors
/// on a buildable plan are real failures and propagate.
pub fn tune(cfg: &TuneConfig) -> FftResult<TuneOutcome> {
    let t0 = Instant::now();
    let mut wisdom = Wisdom::new();
    let mut rows: Vec<TuneRow> = Vec::new();
    let mut exhausted = false;
    // The first key always completes: a budget too small to measure
    // anything would otherwise write an empty (useless) wisdom file.
    let mut over = |rows: &Vec<TuneRow>| {
        let hit = t0.elapsed() >= cfg.budget && !rows.is_empty();
        if hit {
            exhausted = true;
        }
        hit
    };

    'fft: for &dtype in &cfg.dtypes {
        for &n in &cfg.sizes {
            if over(&rows) {
                break 'fft;
            }
            let mut best: Option<(u64, Strategy, Algorithm, Kernel)> = None;
            let mut measured = 0usize;
            for (strategy, algorithm, kernel) in fft_candidates(n, dtype) {
                let spec = PlanSpec::new(n)
                    .strategy(strategy)
                    .algorithm(algorithm)
                    .kernel(kernel)
                    .dtype(dtype);
                let m = match measure_fft(spec, &cfg.measure) {
                    Ok(m) => m,
                    // Not in this key's plan space (size/strategy
                    // combination the planner types out, or a SIMD
                    // arm this host cannot serve) — skip.
                    Err(_) => continue,
                };
                measured += 1;
                if best.map_or(true, |(t, _, _, _)| m.median_ns < t) {
                    best = Some((m.median_ns, strategy, algorithm, kernel));
                }
            }
            if let Some((median_ns, strategy, algorithm, kernel)) = best {
                wisdom.insert(
                    n,
                    TuneOp::Fft,
                    dtype,
                    WisdomEntry { strategy, algorithm, kernel, block_len: 0, median_ns },
                )?;
                rows.push(TuneRow {
                    op: TuneOp::Fft,
                    n,
                    dtype,
                    strategy,
                    algorithm,
                    kernel,
                    block_len: 0,
                    median_ns,
                    candidates: measured,
                });
            }
        }
    }

    'ols: for &dtype in &cfg.dtypes {
        for &taps in &cfg.taps {
            if taps == 0 {
                continue;
            }
            if over(&rows) {
                break 'ols;
            }
            // Block-length tuning holds the strategy at the serving
            // default (dual-select — the only one the fixed planes
            // represent) and sweeps the block only; the block is a
            // cost knob, bit-identity is per (strategy, block).
            let taps_re: Vec<f64> =
                (0..taps).map(|i| 0.5_f64.powi(i as i32 % 8)).collect();
            let taps_im = vec![0.0; taps];
            let mut best: Option<(u64, usize)> = None;
            let mut measured = 0usize;
            for block in ols_block_candidates(taps) {
                let m = measure_ols(
                    dtype,
                    Strategy::DualSelect,
                    &taps_re,
                    &taps_im,
                    block,
                    &cfg.measure,
                )?;
                measured += 1;
                if best.map_or(true, |(t, _)| m.median_ns < t) {
                    best = Some((m.median_ns, block));
                }
            }
            if let Some((median_ns, block)) = best {
                wisdom.insert(
                    taps,
                    TuneOp::Ols,
                    dtype,
                    WisdomEntry {
                        strategy: Strategy::DualSelect,
                        algorithm: Algorithm::Auto,
                        kernel: Kernel::Auto,
                        block_len: block as u32,
                        median_ns,
                    },
                )?;
                rows.push(TuneRow {
                    op: TuneOp::Ols,
                    n: taps,
                    dtype,
                    strategy: Strategy::DualSelect,
                    algorithm: Algorithm::Auto,
                    kernel: Kernel::Auto,
                    block_len: block,
                    median_ns,
                    candidates: measured,
                });
            }
        }
    }

    Ok(TuneOutcome { wisdom, rows, budget_exhausted: exhausted })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_candidate_space_matches_plan_space() {
        // Fixed dtypes: dual-select × Stockham only.
        assert_eq!(
            fft_candidates(64, DType::I16),
            vec![(Strategy::DualSelect, Algorithm::Stockham, Kernel::Auto)]
        );
        // Power of four: Stockham + DIT for all four strategies,
        // radix-4 for the three ratio strategies, Bluestein for all —
        // plus the mixed-radix engine per kernel arm for the three
        // ratio strategies (64 = 2^6 is 2,3-smooth).
        let c64 = fft_candidates(64, DType::F32);
        assert_eq!(c64.len(), 4 * 3 + 3 + 3 * 2);
        assert!(c64.contains(&(Strategy::Cosine, Algorithm::Radix4, Kernel::Auto)));
        assert!(!c64.contains(&(Strategy::Standard, Algorithm::Radix4, Kernel::Auto)));
        assert!(c64.contains(&(Strategy::DualSelect, Algorithm::MixedRadix, Kernel::Scalar)));
        assert!(c64.contains(&(Strategy::DualSelect, Algorithm::MixedRadix, Kernel::Simd)));
        assert!(!c64.iter().any(|&(s, a, _)| s == Strategy::Standard
            && a == Algorithm::MixedRadix));
        // Power of two, not of four: no radix-4 candidates.
        let c128 = fft_candidates(128, DType::F32);
        assert!(c128.iter().all(|&(_, a, _)| a != Algorithm::Radix4));
        // Non-power-of-two, not 2,3-smooth: Bluestein only.
        let c60 = fft_candidates(60, DType::F64);
        assert!(c60.iter().all(|&(_, a, _)| a == Algorithm::Bluestein));
        assert_eq!(c60.len(), 4);
        // Smooth composite: Bluestein everywhere plus mixed-radix per
        // arm for the ratio strategies.
        let c48 = fft_candidates(48, DType::F64);
        assert_eq!(c48.len(), 4 + 3 * 2);
        assert!(c48.contains(&(Strategy::LinzerFeig, Algorithm::MixedRadix, Kernel::Simd)));
        // Soft floats have no vector arm, so no SIMD candidates — the
        // scalar mixed-radix arm still competes.
        let c48h = fft_candidates(48, DType::F16);
        assert_eq!(c48h.len(), 4 + 3);
        assert!(c48h.iter().all(|&(_, _, k)| k != Kernel::Simd));
    }

    #[test]
    fn ols_candidates_start_at_the_feasibility_floor() {
        // L=1: 2L-1 = 1, clamped to the minimum transform size 2.
        assert_eq!(ols_block_candidates(1)[0], 2);
        // L=8: 2L-1 = 15 -> 16; ceiling 16L = 128.
        assert_eq!(ols_block_candidates(8), vec![16, 32, 64, 128]);
        for block in ols_block_candidates(33) {
            assert!(block.is_power_of_two() && block >= 65);
        }
    }

    #[test]
    fn tiny_budget_still_tunes_the_first_key() {
        let cfg = TuneConfig {
            sizes: vec![16, 32],
            taps: vec![4],
            dtypes: vec![DType::F32],
            budget: Duration::ZERO,
            measure: MeasureConfig { warmup: 0, reps: 1, frames: 1 },
        };
        let out = tune(&cfg).unwrap();
        assert!(out.budget_exhausted);
        assert_eq!(out.rows.len(), 1, "first key must complete even at zero budget");
        assert!(out.wisdom.fft_strategy(16, DType::F32).is_some());
    }

    #[test]
    fn full_sweep_writes_fft_and_ols_entries() {
        let cfg = TuneConfig {
            sizes: vec![16, 12],
            taps: vec![2],
            dtypes: vec![DType::F32, DType::I16],
            budget: Duration::from_secs(600),
            measure: MeasureConfig { warmup: 0, reps: 1, frames: 1 },
        };
        let out = tune(&cfg).unwrap();
        assert!(!out.budget_exhausted);
        assert!(out.wisdom.fft_strategy(16, DType::F32).is_some());
        // The composite size tunes too (Bluestein vs mixed-radix); the
        // winner round-trips through the wisdom codec with its kernel.
        let e12 = out.wisdom.entry(12, TuneOp::Fft, DType::F32).unwrap();
        assert!(
            e12.algorithm == Algorithm::Bluestein || e12.algorithm == Algorithm::MixedRadix,
            "{:?}",
            e12.algorithm
        );
        // Fixed-point at 12 has no buildable candidate (fixed plans
        // are power-of-two): no entry, no error.
        assert!(out.wisdom.entry(12, TuneOp::Fft, DType::I16).is_none());
        assert_eq!(out.wisdom.fft_strategy(16, DType::I16), Some(Strategy::DualSelect));
        let block = out.wisdom.ols_block(2, DType::F32).unwrap();
        assert!(block.is_power_of_two() && block >= 4);
        assert!(out.wisdom.ols_block(2, DType::I16).is_some());
        // Round-trips through the file codec.
        let bytes = out.wisdom.encode();
        let back = Wisdom::decode_for_host(&bytes, out.wisdom.host()).unwrap();
        assert_eq!(back, out.wisdom);
    }
}
