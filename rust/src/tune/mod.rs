//! `fft::tune` — the autotuning planner with persisted wisdom.
//!
//! FFT plan choice is an empirical question: which butterfly strategy
//! and kernel organization wins at a given size depends on the
//! machine, and the honest way to answer it is to *measure* (the FFTW
//! wisdom discipline).  This module is the crate's measured answer,
//! end to end:
//!
//! * [`measure`] — the deterministic harness: monotonic clock, warmup
//!   then median-of-k repetitions, every buffer pooled before the
//!   first timed repetition so timing is alloc-free.
//! * [`search`] — the candidate enumerator over the *existing* plan
//!   space (Stockham r2/r4, DIT, Bluestein × the four butterfly
//!   strategies; overlap-save FFT blocks pow2 ≥ 2L−1) and the
//!   budget-bounded sweep.  Because every candidate is a plan the
//!   bound/bit-identity suites already cover, tuning can never change
//!   a result bit — it only picks among verified plans.
//! * [`wisdom`] — the persisted winners: a versioned, checksummed,
//!   zero-dependency file keyed by `(n, op, dtype)` and fenced by a
//!   [`host_fingerprint`] so wisdom measured on another machine is
//!   rejected with a typed error instead of silently mis-applied.
//!
//! Serving integration: `fftd --wisdom PATH` loads a file at boot;
//! requests carrying [`crate::fft::StrategyChoice::Auto`] resolve
//! through it at admission (explicit choice > wisdom entry > server
//! default — see `StrategyChoice::resolve_with`), and stream/graph
//! overlap-save opens without an explicit `fft_len` consult it for
//! the tuned block length.  Wisdom is node-local and never crosses
//! the wire.

pub mod measure;
pub mod search;
pub mod wisdom;

pub use measure::{measure_fft, measure_ols, MeasureConfig, Measurement};
pub use search::{fft_candidates, ols_block_candidates, tune, TuneConfig, TuneOutcome, TuneRow};
pub use wisdom::{TuneOp, Wisdom, WisdomEntry, WISDOM_MAGIC, WISDOM_VERSION};

/// A fingerprint of the machine wisdom was measured on: FNV-1a over
/// the compile-time architecture and OS, the available parallelism,
/// and the CPU model reported by `/proc/cpuinfo` (when present).
/// Plan timings don't transfer across any of those boundaries, so a
/// mismatch means the file's measurements are meaningless here and
/// [`Wisdom::decode`] rejects it with a typed error.
pub fn host_fingerprint() -> u64 {
    const BASIS: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = BASIS;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    eat(std::env::consts::ARCH.as_bytes());
    eat(b"|");
    eat(std::env::consts::OS.as_bytes());
    eat(b"|");
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    eat(&(threads as u64).to_le_bytes());
    eat(b"|");
    if let Ok(cpuinfo) = std::fs::read_to_string("/proc/cpuinfo") {
        if let Some(line) = cpuinfo.lines().find(|l| l.starts_with("model name")) {
            eat(line.as_bytes());
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_fingerprint_is_stable_within_a_process() {
        let a = host_fingerprint();
        let b = host_fingerprint();
        assert_eq!(a, b);
        assert_ne!(a, 0);
    }
}
