//! The persisted wisdom file: measured plan winners, keyed by
//! `(n, op, dtype)` and fenced by a host fingerprint.
//!
//! The format follows the wire codec's discipline (`PROTOCOL.md`
//! framing, [`crate::net::wire::checksum`] FNV-1a integrity, tag
//! values pinned to this file — never derived from enum order), but
//! wisdom is strictly **node-local**: it describes *this machine's*
//! measured preferences and never crosses the wire.  A file recorded
//! on another host fails decode with a typed
//! [`FftError::Protocol`] — stale foreign wisdom is ignored, not
//! silently applied.
//!
//! Every malformation — truncation, bad magic, checksum mismatch,
//! unknown version, unknown op/dtype/strategy/algorithm tag, an entry
//! violating the plan-space invariants (fixed-point entries must be
//! dual-select; OLS blocks must be powers of two ≥ 2L−1) — is a typed
//! [`FftError::Protocol`] and never a panic, so a corrupt file
//! degrades the server to its defaults instead of taking it down.
//!
//! ```text
//! offset  size  field
//! 0       4     magic "FWIS"
//! 4       2     version (little-endian u16) = 1
//! 6       2     reserved (zero)
//! 8       8     host fingerprint (tune::host_fingerprint)
//! 16      4     entry count (u32)
//! 20      24*k  entries
//! 20+24k  4     FNV-1a checksum over bytes [0, 20+24k)
//!
//! entry:  n u64 | op u8 | dtype u8 | strategy u8 | algo_kernel u8
//!         | block_len u32 | median_ns u64
//! ```
//!
//! The `algo_kernel` byte packs two nibbles: algorithm tag in the low
//! nibble, kernel tag ([`Kernel::Auto`] = 0, scalar = 1, simd = 2) in
//! the high nibble.  Files written before the kernel axis existed
//! carry 0 in the high nibble and load as `Kernel::Auto` — the codec
//! change is backward compatible without a version bump.  Unknown
//! nibble values in either half are typed [`FftError::Protocol`]
//! errors, never panics.

use std::collections::BTreeMap;
use std::path::Path;

use crate::fft::{Algorithm, DType, FftError, FftResult, Strategy};
use crate::kernel::Kernel;
use crate::net::wire::checksum;
use crate::stream::min_ols_block;

/// Wisdom file magic.
pub const WISDOM_MAGIC: [u8; 4] = *b"FWIS";
/// Wisdom file format version.
pub const WISDOM_VERSION: u16 = 1;

const HEADER_LEN: usize = 20;
const ENTRY_LEN: usize = 24;

/// Which tuned operation an entry describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TuneOp {
    /// A complex FFT plan of size `n` (covers forward and inverse —
    /// the factorization cost is direction-independent).
    Fft,
    /// An overlap-save FIR block-length choice for `n` taps.
    Ols,
}

impl TuneOp {
    pub fn name(self) -> &'static str {
        match self {
            TuneOp::Fft => "fft",
            TuneOp::Ols => "ols",
        }
    }
}

// Tag values are pinned here explicitly, wire-codec style.

fn op_code(op: TuneOp) -> u8 {
    match op {
        TuneOp::Fft => 0,
        TuneOp::Ols => 1,
    }
}

fn op_from(code: u8) -> FftResult<TuneOp> {
    match code {
        0 => Ok(TuneOp::Fft),
        1 => Ok(TuneOp::Ols),
        other => Err(FftError::Protocol(format!("wisdom: unknown op tag {other}"))),
    }
}

fn strategy_code(s: Strategy) -> u8 {
    match s {
        Strategy::Standard => 0,
        Strategy::LinzerFeig => 1,
        Strategy::Cosine => 2,
        Strategy::DualSelect => 3,
    }
}

fn strategy_from(code: u8) -> FftResult<Strategy> {
    match code {
        0 => Ok(Strategy::Standard),
        1 => Ok(Strategy::LinzerFeig),
        2 => Ok(Strategy::Cosine),
        3 => Ok(Strategy::DualSelect),
        other => Err(FftError::Protocol(format!(
            "wisdom: unknown strategy tag {other}"
        ))),
    }
}

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F64 => 0,
        DType::F32 => 1,
        DType::Bf16 => 2,
        DType::F16 => 3,
        DType::I16 => 4,
        DType::I32 => 5,
    }
}

fn dtype_from(code: u8) -> FftResult<DType> {
    match code {
        0 => Ok(DType::F64),
        1 => Ok(DType::F32),
        2 => Ok(DType::Bf16),
        3 => Ok(DType::F16),
        4 => Ok(DType::I16),
        5 => Ok(DType::I32),
        other => Err(FftError::Protocol(format!(
            "wisdom: unknown dtype tag {other}"
        ))),
    }
}

fn algorithm_code(a: Algorithm) -> u8 {
    match a {
        Algorithm::Auto => 0,
        Algorithm::Stockham => 1,
        Algorithm::Radix4 => 2,
        Algorithm::Dit => 3,
        Algorithm::Bluestein => 4,
        Algorithm::MixedRadix => 5,
    }
}

fn algorithm_from(code: u8) -> FftResult<Algorithm> {
    match code {
        0 => Ok(Algorithm::Auto),
        1 => Ok(Algorithm::Stockham),
        2 => Ok(Algorithm::Radix4),
        3 => Ok(Algorithm::Dit),
        4 => Ok(Algorithm::Bluestein),
        5 => Ok(Algorithm::MixedRadix),
        other => Err(FftError::Protocol(format!(
            "wisdom: unknown algorithm tag {other}"
        ))),
    }
}

fn kernel_code(k: Kernel) -> u8 {
    match k {
        Kernel::Auto => 0,
        Kernel::Scalar => 1,
        Kernel::Simd => 2,
    }
}

fn kernel_from(code: u8) -> FftResult<Kernel> {
    match code {
        0 => Ok(Kernel::Auto),
        1 => Ok(Kernel::Scalar),
        2 => Ok(Kernel::Simd),
        other => Err(FftError::Protocol(format!(
            "wisdom: unknown kernel tag {other}"
        ))),
    }
}

/// Pack the algorithm/kernel pair into the entry's `algo_kernel` byte.
fn algo_kernel_byte(a: Algorithm, k: Kernel) -> u8 {
    algorithm_code(a) | (kernel_code(k) << 4)
}

/// Split the `algo_kernel` byte back into its halves.  Pre-kernel
/// files carry 0 in the high nibble, which is exactly `Kernel::Auto`.
fn algo_kernel_from(byte: u8) -> FftResult<(Algorithm, Kernel)> {
    Ok((algorithm_from(byte & 0x0f)?, kernel_from(byte >> 4)?))
}

/// One measured winner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WisdomEntry {
    /// Winning butterfly strategy (what `Auto` resolution applies).
    pub strategy: Strategy,
    /// Winning FFT organization — recorded for the perf trajectory;
    /// `Auto` resolution applies the strategy only, so tuned requests
    /// keep batching with explicit ones.
    pub algorithm: Algorithm,
    /// Winning butterfly kernel (mixed-radix dispatch arm choice) —
    /// recorded alongside the algorithm; files written before the
    /// kernel axis existed load as [`Kernel::Auto`].
    pub kernel: Kernel,
    /// OLS entries: the winning FFT block length.  Zero for FFT
    /// entries.
    pub block_len: u32,
    /// Median measured time of the winner, for reports.
    pub median_ns: u64,
}

/// Loaded (or under-construction) wisdom: a validated map from
/// `(n, op, dtype)` to the measured winner, stamped with the host it
/// was measured on.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Wisdom {
    host: u64,
    entries: BTreeMap<(u64, u8, u8), WisdomEntry>,
}

impl Wisdom {
    /// Empty wisdom for the current machine.
    pub fn new() -> Self {
        Self::for_host(super::host_fingerprint())
    }

    /// Empty wisdom for an explicit host fingerprint (tests, tooling).
    pub fn for_host(host: u64) -> Self {
        Wisdom { host, entries: BTreeMap::new() }
    }

    pub fn host(&self) -> u64 {
        self.host
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Validate one entry against the plan-space invariants the rest
    /// of the crate relies on.
    fn validate(n: u64, op: TuneOp, dtype: DType, e: &WisdomEntry) -> FftResult<()> {
        if n == 0 {
            return Err(FftError::Protocol("wisdom: entry has n = 0".into()));
        }
        if dtype.is_fixed() && e.strategy != Strategy::DualSelect {
            return Err(FftError::Protocol(format!(
                "wisdom: fixed-point entry names strategy {}, but only dual-select \
                 is representable in a signed Q-format",
                e.strategy
            )));
        }
        match op {
            TuneOp::Fft => {
                if e.block_len != 0 {
                    return Err(FftError::Protocol(format!(
                        "wisdom: fft entry carries a block length ({})",
                        e.block_len
                    )));
                }
            }
            TuneOp::Ols => {
                let taps = usize::try_from(n).map_err(|_| {
                    FftError::Protocol(format!("wisdom: ols tap count {n} overflows usize"))
                })?;
                let block = e.block_len as usize;
                if !block.is_power_of_two() || block < min_ols_block(taps) {
                    return Err(FftError::Protocol(format!(
                        "wisdom: ols block {block} for {taps} taps is not a power of two \
                         >= {}",
                        min_ols_block(taps)
                    )));
                }
            }
        }
        Ok(())
    }

    /// Record a measured winner (replacing any previous entry for the
    /// key).  Invalid entries are rejected with the same typed error
    /// decode would raise — wisdom never holds a value resolution
    /// could trip over.
    pub fn insert(
        &mut self,
        n: usize,
        op: TuneOp,
        dtype: DType,
        entry: WisdomEntry,
    ) -> FftResult<()> {
        let n = n as u64;
        Self::validate(n, op, dtype, &entry)?;
        self.entries.insert((n, op_code(op), dtype_code(dtype)), entry);
        Ok(())
    }

    /// The recorded winner for `(n, op, dtype)`, if any.
    pub fn entry(&self, n: usize, op: TuneOp, dtype: DType) -> Option<&WisdomEntry> {
        self.entries.get(&(n as u64, op_code(op), dtype_code(dtype)))
    }

    /// The tuned strategy for an `n`-point FFT in `dtype` — what the
    /// coordinator applies when resolving
    /// [`crate::fft::StrategyChoice::Auto`].
    pub fn fft_strategy(&self, n: usize, dtype: DType) -> Option<Strategy> {
        self.entry(n, TuneOp::Fft, dtype).map(|e| e.strategy)
    }

    /// The tuned overlap-save FFT block length for a `taps`-tap filter
    /// in `dtype` — what the stream and graph planes consult when a
    /// spec carries no explicit `fft_len` override.
    pub fn ols_block(&self, taps: usize, dtype: DType) -> Option<usize> {
        self.entry(taps, TuneOp::Ols, dtype).map(|e| e.block_len as usize)
    }

    /// Iterate entries in key order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, TuneOp, DType, &WisdomEntry)> {
        self.entries.iter().map(|(&(n, op, dt), e)| {
            // Keys were validated on insert/decode; the tags are known.
            (
                n as usize,
                op_from(op).expect("validated op tag"),
                dtype_from(dt).expect("validated dtype tag"),
                e,
            )
        })
    }

    /// Serialize to the checksummed file format.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + ENTRY_LEN * self.entries.len() + 4);
        out.extend_from_slice(&WISDOM_MAGIC);
        out.extend_from_slice(&WISDOM_VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&self.host.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (&(n, op, dt), e) in &self.entries {
            out.extend_from_slice(&n.to_le_bytes());
            out.push(op);
            out.push(dt);
            out.push(strategy_code(e.strategy));
            out.push(algo_kernel_byte(e.algorithm, e.kernel));
            out.extend_from_slice(&e.block_len.to_le_bytes());
            out.extend_from_slice(&e.median_ns.to_le_bytes());
        }
        let sum = checksum(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Decode and validate, accepting only wisdom recorded for `host`.
    /// Every failure is a typed [`FftError::Protocol`]; this never
    /// panics on hostile input.
    pub fn decode_for_host(bytes: &[u8], host: u64) -> FftResult<Wisdom> {
        if bytes.len() < HEADER_LEN + 4 {
            return Err(FftError::Protocol(format!(
                "wisdom: truncated file ({} bytes; header + checksum need {})",
                bytes.len(),
                HEADER_LEN + 4
            )));
        }
        if bytes[0..4] != WISDOM_MAGIC {
            return Err(FftError::Protocol(format!(
                "wisdom: bad magic {:02x?} (expected {WISDOM_MAGIC:02x?})",
                &bytes[0..4]
            )));
        }
        let body = &bytes[..bytes.len() - 4];
        let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        let computed = checksum(body);
        if stored != computed {
            return Err(FftError::Protocol(format!(
                "wisdom: checksum mismatch (stored {stored:#010x}, computed {computed:#010x})"
            )));
        }
        let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
        if version != WISDOM_VERSION {
            return Err(FftError::Protocol(format!(
                "wisdom: unknown version {version} (this build speaks {WISDOM_VERSION})"
            )));
        }
        let file_host = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        if file_host != host {
            return Err(FftError::Protocol(format!(
                "wisdom: foreign host fingerprint {file_host:#018x} (this machine is \
                 {host:#018x}); re-run `fmafft tune` here"
            )));
        }
        let count = u32::from_le_bytes(bytes[16..20].try_into().unwrap()) as usize;
        let expected = HEADER_LEN + ENTRY_LEN * count + 4;
        if bytes.len() != expected {
            return Err(FftError::Protocol(format!(
                "wisdom: {count} entries need {expected} bytes, file has {}",
                bytes.len()
            )));
        }
        let mut wisdom = Wisdom::for_host(host);
        for i in 0..count {
            let at = HEADER_LEN + ENTRY_LEN * i;
            let e = &bytes[at..at + ENTRY_LEN];
            let n = u64::from_le_bytes(e[0..8].try_into().unwrap());
            let op = op_from(e[8])?;
            let dtype = dtype_from(e[9])?;
            let (algorithm, kernel) = algo_kernel_from(e[11])?;
            let entry = WisdomEntry {
                strategy: strategy_from(e[10])?,
                algorithm,
                kernel,
                block_len: u32::from_le_bytes(e[12..16].try_into().unwrap()),
                median_ns: u64::from_le_bytes(e[16..24].try_into().unwrap()),
            };
            Self::validate(n, op, dtype, &entry)?;
            wisdom.entries.insert((n, e[8], e[9]), entry);
        }
        Ok(wisdom)
    }

    /// [`Wisdom::decode_for_host`] against the current machine's
    /// fingerprint.
    pub fn decode(bytes: &[u8]) -> FftResult<Wisdom> {
        Self::decode_for_host(bytes, super::host_fingerprint())
    }

    /// Write the encoded file to `path`.
    pub fn save(&self, path: &Path) -> FftResult<()> {
        std::fs::write(path, self.encode()).map_err(|e| {
            FftError::Backend(format!("writing wisdom {}: {e}", path.display()))
        })
    }

    /// Read and decode `path` for the current machine.
    pub fn load(path: &Path) -> FftResult<Wisdom> {
        let bytes = std::fs::read(path).map_err(|e| {
            FftError::Backend(format!("reading wisdom {}: {e}", path.display()))
        })?;
        Self::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(strategy: Strategy) -> WisdomEntry {
        WisdomEntry {
            strategy,
            algorithm: Algorithm::Stockham,
            kernel: Kernel::Auto,
            block_len: 0,
            median_ns: 100,
        }
    }

    #[test]
    fn insert_validates_like_decode() {
        let mut w = Wisdom::for_host(7);
        // Fixed dtypes only hold dual-select.
        assert!(matches!(
            w.insert(64, TuneOp::Fft, DType::I16, entry(Strategy::Cosine)),
            Err(FftError::Protocol(_))
        ));
        w.insert(64, TuneOp::Fft, DType::I16, entry(Strategy::DualSelect)).unwrap();
        // FFT entries carry no block length.
        assert!(w
            .insert(
                64,
                TuneOp::Fft,
                DType::F32,
                WisdomEntry { block_len: 64, ..entry(Strategy::DualSelect) }
            )
            .is_err());
        // OLS blocks must be pow2 >= 2L-1.
        assert!(w
            .insert(
                8,
                TuneOp::Ols,
                DType::F32,
                WisdomEntry { block_len: 8, ..entry(Strategy::DualSelect) }
            )
            .is_err());
        w.insert(
            8,
            TuneOp::Ols,
            DType::F32,
            WisdomEntry { block_len: 16, ..entry(Strategy::DualSelect) },
        )
        .unwrap();
        assert_eq!(w.ols_block(8, DType::F32), Some(16));
        assert_eq!(w.ols_block(8, DType::F64), None);
    }

    #[test]
    fn algo_kernel_byte_roundtrips_every_pair() {
        for a in [
            Algorithm::Auto,
            Algorithm::Stockham,
            Algorithm::Radix4,
            Algorithm::Dit,
            Algorithm::Bluestein,
            Algorithm::MixedRadix,
        ] {
            for k in Kernel::ALL {
                let byte = algo_kernel_byte(a, k);
                assert_eq!(algo_kernel_from(byte).unwrap(), (a, k));
            }
        }
        // A pre-kernel byte (high nibble 0) is plain algorithm + Auto.
        assert_eq!(
            algo_kernel_from(algorithm_code(Algorithm::Bluestein)).unwrap(),
            (Algorithm::Bluestein, Kernel::Auto)
        );
        // Foreign nibbles in either half: typed errors, not panics.
        assert!(matches!(algo_kernel_from(0x0f), Err(FftError::Protocol(_))));
        assert!(matches!(algo_kernel_from(0xf0), Err(FftError::Protocol(_))));
    }

    #[test]
    fn resolution_is_keyed_on_all_three_fields() {
        let mut w = Wisdom::for_host(1);
        w.insert(256, TuneOp::Fft, DType::F32, entry(Strategy::Cosine)).unwrap();
        assert_eq!(w.fft_strategy(256, DType::F32), Some(Strategy::Cosine));
        assert_eq!(w.fft_strategy(256, DType::F16), None);
        assert_eq!(w.fft_strategy(512, DType::F32), None);
        assert_eq!(w.ols_block(256, DType::F32), None);
    }
}
