//! Precomputed radix-2 Stockham plans: every pass's twiddle table is
//! built once (in f64, rounded once into `T`) and reused across
//! executions.
//!
//! [`Plan::new`] is the legacy direct-construction path and stays as a
//! thin shim; new code should describe transforms with
//! [`super::PlanSpec`] and cache them in the [`super::Planner`] (which
//! also covers radix-4, DIT, Bluestein and real-input plans).

use crate::precision::{Real, SplitBuf};

use super::twiddle::{pass_angles, plain_table, ratio_table, PlainTable, RatioTable};
use super::{log2_exact, Direction, FftResult, Strategy};

/// Precomputed table for one Stockham pass.
///
/// (The constant-`sel` runs a segment-dispatching kernel would need
/// are stored inside the [`RatioTable`] itself — built once in
/// `ratio_table`, borrowed via `RatioTable::segments`, never
/// recomputed or reallocated on the execute path.)
#[derive(Clone, Debug)]
pub struct PassTable<T> {
    /// Stride (= twiddle count) of this pass: `2^p`.
    pub s: usize,
    pub kind: PassKind<T>,
    /// True when the (ratio) table is exactly W^0 everywhere — the
    /// butterfly degenerates to add/sub (see `RatioTable::is_trivial`).
    pub trivial: bool,
}

#[derive(Clone, Debug)]
pub enum PassKind<T> {
    Plain(PlainTable<T>),
    Ratio(RatioTable<T>),
}

/// A fully-precomputed transform plan.
#[derive(Clone, Debug)]
pub struct Plan<T: Real> {
    pub n: usize,
    pub strategy: Strategy,
    pub direction: Direction,
    pub passes: Vec<PassTable<T>>,
}

impl<T: Real> Plan<T> {
    /// Build a plan (computes all twiddle tables in f64, rounds once
    /// into `T`).
    ///
    /// Legacy shim: prefer `PlanSpec::new(n).strategy(..).build()` —
    /// it routes non-power-of-two sizes to Bluestein instead of
    /// erroring and returns the same transform behind the
    /// [`super::Transform`] trait.
    pub fn new(n: usize, strategy: Strategy, direction: Direction) -> FftResult<Self> {
        let m = log2_exact(n)?;
        let mut passes = Vec::with_capacity(m as usize);
        for p in 0..m {
            let angles = pass_angles(n, p, direction);
            let kind = match strategy {
                Strategy::Standard => PassKind::Plain(plain_table(&angles)),
                _ => PassKind::Ratio(ratio_table(&angles, strategy)),
            };
            let trivial = match &kind {
                PassKind::Ratio(t) => t.is_trivial(),
                PassKind::Plain(_) => false,
            };
            passes.push(PassTable { s: 1 << p, kind, trivial });
        }
        Ok(Plan { n, strategy, direction, passes })
    }

    /// Number of butterfly passes (`log2 n`).
    pub fn num_passes(&self) -> usize {
        self.passes.len()
    }

    /// Execute in-place (with caller-provided scratch of the same size).
    pub fn execute(&self, buf: &mut SplitBuf<T>, scratch: &mut SplitBuf<T>) {
        super::stockham::execute(self, buf, scratch);
    }

    /// Convenience: allocate scratch internally (not for the hot path).
    pub fn execute_alloc(&self, buf: &mut SplitBuf<T>) {
        let mut scratch = SplitBuf::zeroed(self.n);
        self.execute(buf, &mut scratch);
    }

    /// Total twiddle-table bytes (for the paper's storage-overhead
    /// discussion: dual-select adds one select bit per factor).
    pub fn table_bytes(&self) -> usize {
        let scalar = core::mem::size_of::<T>();
        self.passes
            .iter()
            .map(|p| match &p.kind {
                PassKind::Plain(t) => (t.wr.len() + t.wi.len()) * scalar,
                PassKind::Ratio(t) => {
                    (t.m1.len() + t.m2.len() + t.t.len()) * scalar + t.sel.len()
                }
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::FftError;

    #[test]
    fn plan_has_log2n_passes() {
        let plan = Plan::<f32>::new(1024, Strategy::DualSelect, Direction::Forward).unwrap();
        assert_eq!(plan.num_passes(), 10);
        for (p, pass) in plan.passes.iter().enumerate() {
            assert_eq!(pass.s, 1 << p);
            match &pass.kind {
                PassKind::Ratio(t) => assert_eq!(t.t.len(), 1 << p),
                _ => panic!("dual-select plan must use ratio tables"),
            }
        }
    }

    #[test]
    fn plan_rejects_non_power_of_two_with_typed_error() {
        assert_eq!(
            Plan::<f32>::new(768, Strategy::DualSelect, Direction::Forward).unwrap_err(),
            FftError::NonPowerOfTwo { n: 768 }
        );
        assert_eq!(
            Plan::<f32>::new(0, Strategy::DualSelect, Direction::Forward).unwrap_err(),
            FftError::NonPowerOfTwo { n: 0 }
        );
    }

    #[test]
    fn standard_plan_uses_plain_tables() {
        let plan = Plan::<f64>::new(64, Strategy::Standard, Direction::Forward).unwrap();
        assert!(plan
            .passes
            .iter()
            .all(|p| matches!(p.kind, PassKind::Plain(_))));
    }

    #[test]
    fn storage_overhead_matches_paper() {
        // Paper §III: the select flag costs one bit (here one byte) per
        // twiddle factor; the ratio table itself is 3 scalars/factor.
        let plan = Plan::<f32>::new(1024, Strategy::DualSelect, Direction::Forward).unwrap();
        let factors: usize = plan.passes.iter().map(|p| p.s).sum();
        assert_eq!(factors, 1023); // sum 2^p, p<10
        assert_eq!(plan.table_bytes(), factors * (3 * 4 + 1));
    }

    #[test]
    fn execute_alloc_smoke() {
        let plan = Plan::<f64>::new(8, Strategy::DualSelect, Direction::Forward).unwrap();
        let mut buf = SplitBuf::from_f64(&[1.0; 8], &[0.0; 8]);
        plan.execute_alloc(&mut buf);
        // FFT of constant 1 = n·δ_0
        assert!((buf.re[0] - 8.0).abs() < 1e-12);
        for k in 1..8 {
            assert!(buf.re[k].abs() < 1e-12);
        }
    }
}
