//! Twiddle-table construction — the paper's Algorithm 1 and the two
//! clamped baselines, mirrored from `python/compile/twiddle.py`.
//!
//! Tables are always computed in f64 and rounded **once** into the
//! working precision `T`, matching how production FFTs build tables.
//!
//! Branch-free entry layout (see the Python module docstring for the
//! derivation, including the paper's eq. (4) s2 typo):
//!
//! ```text
//! u  = sel ? br : bi        v  = sel ? bi : br
//! s1 = u - t*v              s2 = v + t*u
//! Ar = ar + m1*s1           Br = ar - m1*s1
//! Ai = ai + m2*s2           Bi = ai - m2*s2
//! ```
//!
//! with `m1 = σ·mult`, `m2 = mult`, `σ = +1` on the cosine path and
//! `-1` on the sine path — six FMAs per butterfly on either path.

use crate::precision::Real;

use super::{Direction, Strategy};

/// The epsilon used to clamp the singular baselines' denominators
/// ("standard practice", paper §I).
pub const CLAMP_EPS: f64 = 1e-7;

/// One pass worth of precomputed ratio-butterfly table entries.
#[derive(Clone, Debug)]
pub struct RatioTable<T> {
    /// Signed outer multiplier for the s1 lane (σ·mult).
    pub m1: Vec<T>,
    /// Outer multiplier for the s2 lane (mult).
    pub m2: Vec<T>,
    /// The bounded precomputed ratio (tan θ or cot θ).
    pub t: Vec<T>,
    /// True where the cosine path was selected (the paper's one-bit
    /// flag; here a bool lane so kernels can be branchy or branch-free).
    pub sel: Vec<bool>,
    /// Maximal constant-`sel` runs, precomputed at table build time
    /// (see [`RatioTable::segments`]).
    segments: Vec<(usize, usize, bool)>,
}

/// Maximal runs of constant `sel`, as `(start, end, cos_path)`.
fn compute_segments(sel: &[bool]) -> Vec<(usize, usize, bool)> {
    let mut out = Vec::new();
    let mut start = 0usize;
    for j in 1..=sel.len() {
        if j == sel.len() || sel[j] != sel[start] {
            out.push((start, j, sel[start]));
            start = j;
        }
    }
    out
}

impl<T: Real> RatioTable<T> {
    /// Maximal runs of constant `sel`, as `(start, end, cos_path)`.
    ///
    /// Because the dual-select rule compares |cos θ| with |sin θ| and
    /// the pass angles are monotone in j, `sel` changes at most a few
    /// times per pass — a kernel can iterate run-by-run with the path
    /// choice hoisted out (branch-free, vectorizable inner loops; this
    /// is the §Perf L3 iteration 2 optimization).  The runs are
    /// computed once in [`ratio_table`] and stored with the table, so
    /// this accessor is a borrow — safe to call from hot loops, never
    /// allocates.
    pub fn segments(&self) -> &[(usize, usize, bool)] {
        &self.segments
    }

    /// True when every entry is the exact trivial twiddle W^0
    /// (mult = 1, ratio = 0): the butterfly degenerates to add/sub and
    /// the kernel may skip the table entirely.  This is *semantics
    /// preserving*: the clamped LF table at W^0 is NOT trivial (its
    /// huge ratio is the paper's point) and keeps the general path.
    pub fn is_trivial(&self) -> bool {
        self.t.iter().all(|&t| t.to_f64() == 0.0)
            && self.m1.iter().all(|&m| m.to_f64() == 1.0)
            && self.m2.iter().all(|&m| m.to_f64() == 1.0)
    }
}

/// One pass worth of plain (ωr, ωi) entries for the standard butterfly.
#[derive(Clone, Debug)]
pub struct PlainTable<T> {
    pub wr: Vec<T>,
    pub wi: Vec<T>,
}

/// Twiddle angles for Stockham pass `p` of an `n`-point transform:
/// `s = 2^p` angles `θ_j = sign·2π·j·l/n`, `l = n >> (p+1)`.
pub fn pass_angles(n: usize, p: u32, dir: Direction) -> Vec<f64> {
    let s = 1usize << p;
    let l = n >> (p + 1);
    assert!(l >= 1, "pass {p} out of range for n={n}");
    let sign = dir.sign();
    (0..s)
        .map(|j| sign * 2.0 * core::f64::consts::PI * (j * l) as f64 / n as f64)
        .collect()
}

/// Plain (cos, sin) table for the standard butterfly.
pub fn plain_table<T: Real>(angles: &[f64]) -> PlainTable<T> {
    PlainTable {
        wr: angles.iter().map(|&a| T::from_f64(a.cos())).collect(),
        wi: angles.iter().map(|&a| T::from_f64(a.sin())).collect(),
    }
}

/// Whether the cosine path is selected for each angle under `strategy`.
fn cos_path(wr: f64, wi: f64, strategy: Strategy) -> bool {
    match strategy {
        Strategy::DualSelect => wr.abs() >= wi.abs(),
        Strategy::LinzerFeig => false,
        Strategy::Cosine => true,
        Strategy::Standard => unreachable!("standard butterfly has no ratio table"),
    }
}

/// Build the (m1, m2, t, sel) ratio table for one pass.
///
/// For `LinzerFeig`/`Cosine` the denominator is clamped to
/// [`CLAMP_EPS`]; `DualSelect` never needs it (Theorem 1).
pub fn ratio_table<T: Real>(angles: &[f64], strategy: Strategy) -> RatioTable<T> {
    let mut out = RatioTable {
        m1: Vec::with_capacity(angles.len()),
        m2: Vec::with_capacity(angles.len()),
        t: Vec::with_capacity(angles.len()),
        sel: Vec::with_capacity(angles.len()),
        segments: Vec::new(),
    };
    for &a in angles {
        let (wr, wi) = (a.cos(), a.sin());
        let cosine = cos_path(wr, wi, strategy);
        let mut mult = if cosine { wr } else { wi };
        if strategy != Strategy::DualSelect && mult.abs() < CLAMP_EPS {
            mult = if mult < 0.0 { -CLAMP_EPS } else { CLAMP_EPS };
        }
        let num = if cosine { wi } else { wr };
        let t = num / mult;
        let sigma = if cosine { 1.0 } else { -1.0 };
        out.m1.push(T::from_f64(sigma * mult));
        out.m2.push(T::from_f64(mult));
        out.t.push(T::from_f64(t));
        out.sel.push(cosine);
    }
    out.segments = compute_segments(&out.sel);
    out
}

/// The paper's Algorithm 1 over the flat twiddle index `k ∈ [0, n/2)`:
/// returns `(mult, ratio, sel)` in f64 — the audit/analysis form.
pub fn dual_select_flat(n: usize, dir: Direction) -> (Vec<f64>, Vec<f64>, Vec<bool>) {
    let half = n / 2;
    let sign = dir.sign();
    let mut mult = Vec::with_capacity(half);
    let mut ratio = Vec::with_capacity(half);
    let mut sel = Vec::with_capacity(half);
    for k in 0..half {
        let theta = sign * 2.0 * core::f64::consts::PI * k as f64 / n as f64;
        let (wr, wi) = (theta.cos(), theta.sin());
        let cosine = wr.abs() >= wi.abs();
        let m = if cosine { wr } else { wi };
        mult.push(m);
        ratio.push(if cosine { wi } else { wr } / m);
        sel.push(cosine);
    }
    (mult, ratio, sel)
}

/// DIT stage twiddles: stage with butterfly span `len = 2^(stage+1)`
/// uses `W_n^{j·(n/len)}` for `j ∈ [0, len/2)` — same factor set as the
/// Stockham passes, different iteration order.
pub fn dit_stage_angles(n: usize, stage: u32, dir: Direction) -> Vec<f64> {
    let len = 1usize << (stage + 1);
    let half = len / 2;
    let step = n / len;
    let sign = dir.sign();
    (0..half)
        .map(|j| sign * 2.0 * core::f64::consts::PI * (j * step) as f64 / n as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::log2_exact;

    #[test]
    fn dual_select_bound_holds_for_all_sizes() {
        for n in [2usize, 4, 8, 16, 64, 256, 1024, 4096, 16384] {
            let (_, ratio, _) = dual_select_flat(n, Direction::Forward);
            for (k, r) in ratio.iter().enumerate() {
                assert!(r.abs() <= 1.0 + 1e-15, "n={n} k={k} |t|={}", r.abs());
            }
        }
    }

    #[test]
    fn dual_select_multiplier_at_least_invsqrt2() {
        let (mult, _, _) = dual_select_flat(1024, Direction::Forward);
        for m in mult {
            assert!(m.abs() >= core::f64::consts::FRAC_1_SQRT_2 - 1e-15);
        }
    }

    #[test]
    fn path_split_is_50_50_for_n1024() {
        let (_, _, sel) = dual_select_flat(1024, Direction::Forward);
        let cos_count = sel.iter().filter(|&&c| c).count();
        assert_eq!(cos_count, 256);
        assert_eq!(sel.len() - cos_count, 256);
    }

    #[test]
    fn dual_max_ratio_is_exactly_one_at_n_over_8() {
        let (_, ratio, _) = dual_select_flat(1024, Direction::Forward);
        let max = ratio.iter().fold(0.0f64, |w, r| w.max(r.abs()));
        assert!((max - 1.0).abs() < 1e-12);
        // |t| = 1 exactly where |cos| = |sin|: k = N/8 (θ=-π/4) and its
        // mirror k = 3N/8 (θ=-3π/4). The paper cites k=N/8.
        assert!((ratio[128].abs() - 1.0).abs() < 1e-12);
        assert!((ratio[384].abs() - 1.0).abs() < 1e-12);
        for (k, r) in ratio.iter().enumerate() {
            if k != 128 && k != 384 {
                assert!(r.abs() < 1.0, "k={k}");
            }
        }
    }

    #[test]
    fn lf_table_clamped_at_w0() {
        // Pass 0 has the single twiddle W^0 = 1: the LF denominator
        // sin(0) = 0 gets clamped, storing the huge ratio 1e7.
        let angles = pass_angles(1024, 0, Direction::Forward);
        assert_eq!(angles.len(), 1);
        let t: RatioTable<f64> = ratio_table(&angles, Strategy::LinzerFeig);
        assert!(t.t[0].abs() >= 0.99 / CLAMP_EPS);
        assert!(!t.sel[0]);
    }

    #[test]
    fn cosine_table_clamped_at_n_over_4() {
        // The last pass contains k = n/4 (θ = -π/2) where cos ≈ 6e-17.
        let n = 1024;
        let angles = pass_angles(n, 9, Direction::Forward);
        let t: RatioTable<f64> = ratio_table(&angles, Strategy::Cosine);
        let worst = t.t.iter().fold(0.0f64, |w, &x| w.max(x.abs()));
        assert!(worst >= 0.99 / CLAMP_EPS);
    }

    #[test]
    fn dual_table_bounded_every_pass() {
        let n = 4096;
        for p in 0..log2_exact(n).unwrap() {
            let angles = pass_angles(n, p, Direction::Forward);
            let t: RatioTable<f64> = ratio_table(&angles, Strategy::DualSelect);
            for &x in &t.t {
                assert!(x.abs() <= 1.0 + 1e-15);
            }
            // m1 = σ m2 exactly.
            for i in 0..t.m1.len() {
                let sigma = if t.sel[i] { 1.0 } else { -1.0 };
                assert_eq!(t.m1[i], sigma * t.m2[i]);
            }
        }
    }

    #[test]
    fn pass_angle_union_covers_flat_table() {
        let n = 256;
        let mut seen = std::collections::BTreeSet::new();
        for p in 0..log2_exact(n).unwrap() {
            let l = n >> (p + 1);
            for j in 0..(1usize << p) {
                seen.insert(j * l);
            }
        }
        assert_eq!(seen, (0..n / 2).collect());
    }

    #[test]
    fn inverse_angles_are_conjugate() {
        let fwd = pass_angles(64, 3, Direction::Forward);
        let inv = pass_angles(64, 3, Direction::Inverse);
        for (f, i) in fwd.iter().zip(&inv) {
            assert_eq!(*f, -*i);
        }
    }

    #[test]
    fn dit_stage_angles_match_stockham_factor_set() {
        let n = 64;
        let mut dit: Vec<i64> = Vec::new();
        for stage in 0..log2_exact(n).unwrap() {
            let len = 1usize << (stage + 1);
            for j in 0..len / 2 {
                dit.push((j * (n / len)) as i64);
            }
        }
        dit.sort_unstable();
        dit.dedup();
        assert_eq!(dit, (0..(n / 2) as i64).collect::<Vec<_>>());
    }

    #[test]
    fn segments_are_precomputed_and_borrowed() {
        let angles = pass_angles(1024, 9, Direction::Forward);
        let t: RatioTable<f64> = ratio_table(&angles, Strategy::DualSelect);
        // The accessor borrows the stored runs — same pointer every
        // call, no per-call allocation.
        assert_eq!(t.segments().as_ptr(), t.segments().as_ptr());
        // The runs tile the table, alternate `sel`, and match the lane.
        let mut covered = 0usize;
        let mut prev: Option<bool> = None;
        for &(start, end, cos) in t.segments() {
            assert_eq!(start, covered);
            assert!(end > start);
            covered = end;
            for j in start..end {
                assert_eq!(t.sel[j], cos);
            }
            if let Some(p) = prev {
                assert_ne!(p, cos, "adjacent runs must differ");
            }
            prev = Some(cos);
        }
        assert_eq!(covered, t.sel.len());
    }

    #[test]
    fn tables_round_into_working_precision() {
        use crate::precision::F16;
        let angles = pass_angles(1024, 9, Direction::Forward);
        let t16: RatioTable<F16> = ratio_table(&angles, Strategy::DualSelect);
        // Every dual-select entry is finite and bounded in fp16.
        for (&t, &m) in t16.t.iter().zip(&t16.m2) {
            assert!(t.is_finite());
            assert!(t.to_f64().abs() <= 1.0);
            assert!(m.to_f64().abs() <= 1.0);
        }
        // ... whereas the clamped LF ratio overflows fp16 to inf.
        let lf16: RatioTable<F16> = ratio_table(&angles, Strategy::LinzerFeig);
        assert!(lf16.t.iter().any(|t| !t.is_finite()));
    }
}
