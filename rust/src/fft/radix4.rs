//! Radix-4 Stockham FFT with per-twiddle dual-select — the paper's §VI
//! generality claim in code: *"for radix-r butterflies with FMA
//! factorization, each twiddle multiplication can independently select
//! the min-ratio path"*.
//!
//! Each radix-4 butterfly multiplies by three twiddles (W, W², W³);
//! each multiply independently uses the bounded-ratio form
//! ([`super::butterfly::ratio_twiddle_mul`]), so every precomputed
//! ratio in the radix-4 table is also ≤ 1 in magnitude.

use crate::precision::{Real, SplitBuf};

use super::butterfly::ratio_twiddle_mul;
use super::twiddle::{ratio_table, RatioTable};
use super::{Direction, FftError, FftResult, Strategy};

/// Radix-4 pass tables: one ratio table per twiddle power.
#[derive(Clone, Debug)]
pub struct Radix4Pass<T> {
    pub s: usize,
    pub w1: RatioTable<T>,
    pub w2: RatioTable<T>,
    pub w3: RatioTable<T>,
}

/// Radix-4 Stockham plan for `n = 4^m`.
#[derive(Clone, Debug)]
pub struct Radix4Plan<T: Real> {
    pub n: usize,
    pub strategy: Strategy,
    pub direction: Direction,
    passes: Vec<Radix4Pass<T>>,
}

/// `log4(n)` for exact powers of four.
pub fn log4_exact(n: usize) -> FftResult<u32> {
    if n >= 4 && n.is_power_of_two() && n.trailing_zeros() % 2 == 0 {
        Ok(n.trailing_zeros() / 2)
    } else {
        Err(FftError::InvalidSize { n, reason: "radix-4 FFT size must be a power of four >= 4" })
    }
}

impl<T: Real> Radix4Plan<T> {
    pub fn new(n: usize, strategy: Strategy, direction: Direction) -> FftResult<Self> {
        if strategy == Strategy::Standard {
            return Err(FftError::UnsupportedStrategy {
                strategy,
                reason: "radix-4 plan is ratio-form only (use standard radix-2)",
            });
        }
        let m = log4_exact(n)?;
        let sign = direction.sign();
        let mut passes = Vec::with_capacity(m as usize);
        for p in 0..m {
            let s = 4usize.pow(p);
            let l = n / (4 * s);
            let angle = |mult: usize, j: usize| {
                sign * 2.0 * core::f64::consts::PI * (mult * j * l) as f64 / n as f64
            };
            let a1: Vec<f64> = (0..s).map(|j| angle(1, j)).collect();
            let a2: Vec<f64> = (0..s).map(|j| angle(2, j)).collect();
            let a3: Vec<f64> = (0..s).map(|j| angle(3, j)).collect();
            passes.push(Radix4Pass {
                s,
                w1: ratio_table(&a1, strategy),
                w2: ratio_table(&a2, strategy),
                w3: ratio_table(&a3, strategy),
            });
        }
        Ok(Radix4Plan { n, strategy, direction, passes })
    }

    /// Maximum |ratio| across all three twiddle tables of all passes
    /// (Theorem 1 generalization: ≤ 1 for dual-select).
    pub fn max_ratio(&self) -> f64 {
        let mut worst = 0.0f64;
        for pass in &self.passes {
            for tab in [&pass.w1, &pass.w2, &pass.w3] {
                for &t in &tab.t {
                    worst = worst.max(t.to_f64().abs());
                }
            }
        }
        worst
    }

    /// Slice core: transform one planar frame in place, ping-ponging
    /// with caller-provided scratch planes (all length n).  Odd pass
    /// counts copy the input into scratch first so the result always
    /// lands back in the frame (borrowed frames can't be swapped).
    pub fn execute_in(&self, re: &mut [T], im: &mut [T], sre: &mut [T], sim: &mut [T]) {
        let n = self.n;
        assert_eq!(re.len(), n, "buffer length != plan size");
        assert_eq!(im.len(), n, "buffer length != plan size");
        assert_eq!(sre.len(), n, "scratch length != plan size");
        assert_eq!(sim.len(), n, "scratch length != plan size");
        // Multiply by ±j depending on direction: forward uses -j.
        let fwd = self.direction == Direction::Forward;

        let mut src_in_frame = self.passes.len() % 2 == 0;
        if !src_in_frame {
            sre.copy_from_slice(re);
            sim.copy_from_slice(im);
        }
        for pass in &self.passes {
            if src_in_frame {
                run_radix4_pass(pass, fwd, n, re, im, sre, sim);
            } else {
                run_radix4_pass(pass, fwd, n, sre, sim, re, im);
            }
            src_in_frame = !src_in_frame;
        }
        debug_assert!(src_in_frame, "result must end in the frame");
        if self.direction == Direction::Inverse {
            let inv = T::from_f64(1.0 / n as f64);
            for x in re.iter_mut().chain(im.iter_mut()) {
                *x = *x * inv;
            }
        }
    }

    pub fn execute(&self, buf: &mut SplitBuf<T>, scratch: &mut SplitBuf<T>) {
        let n = self.n;
        assert_eq!(buf.len(), n);
        if scratch.len() != n {
            *scratch = SplitBuf::zeroed(n);
        }
        self.execute_in(&mut buf.re, &mut buf.im, &mut scratch.re, &mut scratch.im);
    }

    /// Convenience wrapper allocating scratch.
    pub fn execute_alloc(&self, buf: &mut SplitBuf<T>) {
        let mut scratch = SplitBuf::zeroed(self.n);
        self.execute(buf, &mut scratch);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_radix4_pass<T: Real>(
    pass: &Radix4Pass<T>,
    fwd: bool,
    n: usize,
    xre: &[T],
    xim: &[T],
    yre: &mut [T],
    yim: &mut [T],
) {
    let s = pass.s;
    let l = n / (4 * s);
    let q = n / 4;
    for k in 0..l {
        let base = k * s;
        let out = 4 * k * s;
        for j in 0..s {
            let i0 = base + j;
            let (t0r, t0i) = (xre[i0], xim[i0]);
            let (t1r, t1i) = ratio_twiddle_mul(
                xre[i0 + q], xim[i0 + q],
                pass.w1.m1[j], pass.w1.m2[j], pass.w1.t[j], pass.w1.sel[j],
            );
            let (t2r, t2i) = ratio_twiddle_mul(
                xre[i0 + 2 * q], xim[i0 + 2 * q],
                pass.w2.m1[j], pass.w2.m2[j], pass.w2.t[j], pass.w2.sel[j],
            );
            let (t3r, t3i) = ratio_twiddle_mul(
                xre[i0 + 3 * q], xim[i0 + 3 * q],
                pass.w3.m1[j], pass.w3.m2[j], pass.w3.t[j], pass.w3.sel[j],
            );

            // Even/odd partial sums.
            let e_r = t0r + t2r;
            let e_i = t0i + t2i;
            let f_r = t0r - t2r;
            let f_i = t0i - t2i;
            let g_r = t1r + t3r;
            let g_i = t1i + t3i;
            let h_r = t1r - t3r;
            let h_i = t1i - t3i;

            // jj = sign·j: forward  jj·h = (h_i, -h_r); inverse (-h_i, h_r).
            let (jh_r, jh_i) = if fwd { (h_i, -h_r) } else { (-h_i, h_r) };

            yre[out + j] = e_r + g_r;
            yim[out + j] = e_i + g_i;
            yre[out + s + j] = f_r + jh_r;
            yim[out + s + j] = f_i + jh_i;
            yre[out + 2 * s + j] = e_r - g_r;
            yim[out + 2 * s + j] = e_i - g_i;
            yre[out + 3 * s + j] = f_r - jh_r;
            yim[out + 3 * s + j] = f_i - jh_i;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft;
    use crate::precision::F16;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    #[test]
    fn log4_accepts_only_powers_of_four() {
        assert_eq!(log4_exact(4), Ok(1));
        assert_eq!(log4_exact(1024), Ok(5));
        assert!(log4_exact(2).is_err());
        assert!(log4_exact(8).is_err());
        assert!(log4_exact(512).is_err());
    }

    #[test]
    fn radix4_matches_dft_oracle() {
        let mut rng = Pcg32::seed(31);
        for n in [4usize, 16, 64, 256, 1024] {
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (wr, wi) = dft::naive_dft(&re, &im, false);
            for strategy in [Strategy::DualSelect, Strategy::LinzerFeig] {
                let plan = Radix4Plan::<f64>::new(n, strategy, Direction::Forward).unwrap();
                let mut buf = SplitBuf::from_f64(&re, &im);
                plan.execute_alloc(&mut buf);
                let (gr, gi) = buf.to_f64();
                let tol = if strategy == Strategy::DualSelect { 1e-12 } else { 5e-6 };
                assert!(rel_l2(&gr, &gi, &wr, &wi) < tol, "n={n} {strategy:?}");
            }
        }
    }

    #[test]
    fn radix4_agrees_with_radix2() {
        let mut rng = Pcg32::seed(32);
        let n = 256;
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let r4 = Radix4Plan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let mut a = SplitBuf::from_f64(&re, &im);
        r4.execute_alloc(&mut a);
        let r2 = super::super::Plan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let mut b = SplitBuf::from_f64(&re, &im);
        r2.execute_alloc(&mut b);
        let (ar, ai) = a.to_f64();
        let (br, bi) = b.to_f64();
        assert!(rel_l2(&ar, &ai, &br, &bi) < 1e-13);
    }

    #[test]
    fn radix4_inverse_roundtrip() {
        let mut rng = Pcg32::seed(33);
        let n = 64;
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let fwd = Radix4Plan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let inv = Radix4Plan::<f64>::new(n, Strategy::DualSelect, Direction::Inverse).unwrap();
        let mut buf = SplitBuf::from_f64(&re, &im);
        fwd.execute_alloc(&mut buf);
        inv.execute_alloc(&mut buf);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &re, &im) < 1e-12);
    }

    #[test]
    fn theorem1_generalizes_to_radix4() {
        // Paper §VI: the |t| ≤ 1 bound is radix-independent.
        for n in [4usize, 16, 256, 4096] {
            let plan = Radix4Plan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
            assert!(plan.max_ratio() <= 1.0 + 1e-15, "n={n}");
        }
        // ... and LF's radix-4 table is NOT bounded (clamped 1e7).
        let lf = Radix4Plan::<f64>::new(256, Strategy::LinzerFeig, Direction::Forward).unwrap();
        assert!(lf.max_ratio() > 1e6);
    }

    #[test]
    fn radix4_fp16_dual_select_accurate() {
        let mut rng = Pcg32::seed(34);
        let n = 256;
        let re: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let (wr, wi) = dft::naive_dft(&re, &im, false);
        let plan = Radix4Plan::<F16>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let mut buf = SplitBuf::<F16>::from_f64(&re, &im);
        plan.execute_alloc(&mut buf);
        let (gr, gi) = buf.to_f64();
        let err = rel_l2(&gr, &gi, &wr, &wi);
        assert!(err < 0.03, "radix-4 fp16 err {err:.3e}");
    }
}
