//! In-place Cooley-Tukey decimation-in-time FFT with bit-reversal —
//! the structural baseline the Stockham transform is compared against
//! (same butterfly kernels, different data movement).
//!
//! Exists to demonstrate the dual-select strategy is independent of
//! FFT organization (the paper's claim is per-*twiddle*, not
//! per-algorithm) and as the ablation baseline for the autosort
//! data-movement benefit.

use crate::precision::{Real, SplitBuf};

use super::twiddle::{dit_stage_angles, plain_table, ratio_table};
use super::{log2_exact, Direction, FftResult, Strategy};

/// Precomputed DIT plan: per-stage twiddle tables.
#[derive(Clone, Debug)]
pub struct DitPlan<T: Real> {
    pub n: usize,
    pub strategy: Strategy,
    pub direction: Direction,
    stages: Vec<super::plan::PassKind<T>>,
}

impl<T: Real> DitPlan<T> {
    pub fn new(n: usize, strategy: Strategy, direction: Direction) -> FftResult<Self> {
        let m = log2_exact(n)?;
        let mut stages = Vec::with_capacity(m as usize);
        for stage in 0..m {
            let angles = dit_stage_angles(n, stage, direction);
            stages.push(match strategy {
                Strategy::Standard => super::plan::PassKind::Plain(plain_table(&angles)),
                _ => super::plan::PassKind::Ratio(ratio_table(&angles, strategy)),
            });
        }
        Ok(DitPlan { n, strategy, direction, stages })
    }

    /// Slice core: execute fully in place over one planar frame
    /// (bit-reversal permutation + stages).  Needs no scratch — the
    /// DIT organization is the in-place baseline.
    pub fn execute_in(&self, re: &mut [T], im: &mut [T]) {
        let n = self.n;
        assert_eq!(re.len(), n, "buffer length != plan size");
        assert_eq!(im.len(), n, "buffer length != plan size");
        bit_reverse_permute(re, im);

        for (stage, kind) in self.stages.iter().enumerate() {
            let len = 1usize << (stage + 1);
            let half = len / 2;
            for base in (0..n).step_by(len) {
                for j in 0..half {
                    let ia = base + j;
                    let ib = base + j + half;
                    let (a_r, a_i, b_r, b_i) = match kind {
                        super::plan::PassKind::Plain(t) => super::butterfly::standard(
                            re[ia], im[ia], re[ib], im[ib], t.wr[j], t.wi[j],
                        ),
                        super::plan::PassKind::Ratio(t) => super::butterfly::ratio(
                            re[ia], im[ia], re[ib], im[ib],
                            t.m1[j], t.m2[j], t.t[j], t.sel[j],
                        ),
                    };
                    re[ia] = a_r;
                    im[ia] = a_i;
                    re[ib] = b_r;
                    im[ib] = b_i;
                }
            }
        }

        if self.direction == Direction::Inverse {
            let inv = T::from_f64(1.0 / n as f64);
            for x in re.iter_mut().chain(im.iter_mut()) {
                *x = *x * inv;
            }
        }
    }

    /// Execute fully in place (bit-reversal permutation + stages).
    pub fn execute(&self, buf: &mut SplitBuf<T>) {
        assert_eq!(buf.len(), self.n);
        self.execute_in(&mut buf.re, &mut buf.im);
    }
}

/// In-place bit-reversal permutation of a split buffer.
pub fn bit_reverse_permute<T: Copy>(re: &mut [T], im: &mut [T]) {
    let n = re.len();
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i.reverse_bits() >> (usize::BITS - bits)) & (n - 1);
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    #[test]
    fn bit_reverse_is_involution() {
        let n = 32;
        let orig: Vec<usize> = (0..n).collect();
        let mut re = orig.clone();
        let mut im = orig.clone();
        bit_reverse_permute(&mut re, &mut im);
        assert_ne!(re, orig);
        bit_reverse_permute(&mut re, &mut im);
        assert_eq!(re, orig);
        assert_eq!(im, orig);
    }

    #[test]
    fn dit_matches_dft_all_strategies() {
        let mut rng = Pcg32::seed(21);
        for n in [2usize, 8, 64, 256] {
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (wr, wi) = dft::naive_dft(&re, &im, false);
            for strategy in Strategy::ALL {
                let plan = DitPlan::<f64>::new(n, strategy, Direction::Forward).unwrap();
                let mut buf = SplitBuf::from_f64(&re, &im);
                plan.execute(&mut buf);
                let (gr, gi) = buf.to_f64();
                let tol = match strategy {
                    Strategy::LinzerFeig | Strategy::Cosine => 5e-6,
                    _ => 1e-12,
                };
                let err = rel_l2(&gr, &gi, &wr, &wi);
                assert!(err < tol, "n={n} {strategy:?} err={err:.3e}");
            }
        }
    }

    #[test]
    fn dit_agrees_with_stockham() {
        let mut rng = Pcg32::seed(22);
        let n = 128;
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();

        let dit = DitPlan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let mut a = SplitBuf::from_f64(&re, &im);
        dit.execute(&mut a);

        let st = super::super::Plan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let mut b = SplitBuf::from_f64(&re, &im);
        st.execute_alloc(&mut b);

        let (ar, ai) = a.to_f64();
        let (br, bi) = b.to_f64();
        assert!(rel_l2(&ar, &ai, &br, &bi) < 1e-13);
    }

    #[test]
    fn dit_inverse_roundtrip() {
        let mut rng = Pcg32::seed(23);
        let n = 64;
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let fwd = DitPlan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let inv = DitPlan::<f64>::new(n, Strategy::DualSelect, Direction::Inverse).unwrap();
        let mut buf = SplitBuf::from_f64(&re, &im);
        fwd.execute(&mut buf);
        inv.execute(&mut buf);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &re, &im) < 1e-12);
    }
}
