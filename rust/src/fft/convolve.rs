//! FFT-based convolution / correlation — the building block of the
//! radar matched filter ([`crate::signal::pulse`]).

use crate::precision::{Real, SplitBuf};

use super::api::{Planner, Transform};
use super::{Direction, FftError, FftResult, Strategy};

/// Pointwise complex multiply `a·b` into `out` (working precision).
pub fn pointwise_mul<T: Real>(a: &SplitBuf<T>, b: &SplitBuf<T>, out: &mut SplitBuf<T>) {
    let n = a.len();
    assert_eq!(b.len(), n);
    assert_eq!(out.len(), n);
    for i in 0..n {
        out.re[i] = a.re[i] * b.re[i] - a.im[i] * b.im[i];
        out.im[i] = a.im[i].mul_add(b.re[i], a.re[i] * b.im[i]);
    }
}

/// Pointwise complex multiply `a ·= b` over planar slices, in place —
/// the zero-copy form the batch execution path uses (identical
/// arithmetic to [`pointwise_mul`]: both outputs are computed from the
/// original `a[i]` before either store).
pub fn pointwise_mul_in<T: Real>(are: &mut [T], aim: &mut [T], bre: &[T], bim: &[T]) {
    let n = are.len();
    assert_eq!(aim.len(), n);
    assert_eq!(bre.len(), n);
    assert_eq!(bim.len(), n);
    for i in 0..n {
        let (ar, ai) = (are[i], aim[i]);
        are[i] = ar * bre[i] - ai * bim[i];
        aim[i] = ai.mul_add(bre[i], ar * bim[i]);
    }
}

/// Pointwise `a·conj(b)` (correlation / matched filtering).
pub fn pointwise_mul_conj<T: Real>(a: &SplitBuf<T>, b: &SplitBuf<T>, out: &mut SplitBuf<T>) {
    let n = a.len();
    assert_eq!(b.len(), n);
    assert_eq!(out.len(), n);
    for i in 0..n {
        out.re[i] = a.re[i].mul_add(b.re[i], a.im[i] * b.im[i]);
        out.im[i] = a.im[i].mul_add(b.re[i], -(a.re[i] * b.im[i]));
    }
}

/// Pointwise `a ·= conj(b)` over planar slices, in place (identical
/// arithmetic to [`pointwise_mul_conj`]).
pub fn pointwise_mul_conj_in<T: Real>(are: &mut [T], aim: &mut [T], bre: &[T], bim: &[T]) {
    let n = are.len();
    assert_eq!(aim.len(), n);
    assert_eq!(bre.len(), n);
    assert_eq!(bim.len(), n);
    for i in 0..n {
        let (ar, ai) = (are[i], aim[i]);
        are[i] = ar.mul_add(bre[i], ai * bim[i]);
        aim[i] = ai.mul_add(bre[i], -(ar * bim[i]));
    }
}

/// Circular convolution of two length-n complex signals via FFT.
pub fn circular_convolve<T: Real>(
    planner: &Planner<T>,
    strategy: Strategy,
    x: &SplitBuf<T>,
    h: &SplitBuf<T>,
) -> FftResult<SplitBuf<T>> {
    let n = x.len();
    if h.len() != n {
        return Err(FftError::LengthMismatch { expected: n, got: h.len() });
    }
    let fwd = planner.plan(n, strategy, Direction::Forward)?;
    let inv = planner.plan(n, strategy, Direction::Inverse)?;

    let mut fx = x.clone();
    let mut fh = h.clone();
    let mut scratch = SplitBuf::zeroed(n);
    fwd.execute(&mut fx, &mut scratch);
    fwd.execute(&mut fh, &mut scratch);

    let mut prod = SplitBuf::zeroed(n);
    pointwise_mul(&fx, &fh, &mut prod);
    inv.execute(&mut prod, &mut scratch);
    Ok(prod)
}

/// Linear convolution via zero-padding to the next power of two
/// >= `x.len() + h.len() - 1`; output has that logical length.
pub fn linear_convolve<T: Real>(
    planner: &Planner<T>,
    strategy: Strategy,
    x: &SplitBuf<T>,
    h: &SplitBuf<T>,
) -> FftResult<SplitBuf<T>> {
    let out_len = x.len() + h.len() - 1;
    let n = out_len.next_power_of_two().max(2);
    let pad = |src: &SplitBuf<T>| {
        let mut p = SplitBuf::<T>::zeroed(n);
        p.re[..src.len()].copy_from_slice(&src.re);
        p.im[..src.len()].copy_from_slice(&src.im);
        p
    };
    let mut full = circular_convolve(planner, strategy, &pad(x), &pad(h))?;
    full.re.truncate(out_len);
    full.im.truncate(out_len);
    Ok(full)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    /// O(N²) direct circular convolution oracle.
    fn direct_circular(xr: &[f64], xi: &[f64], hr: &[f64], hi: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let n = xr.len();
        let mut or_ = vec![0.0; n];
        let mut oi = vec![0.0; n];
        for k in 0..n {
            for j in 0..n {
                let m = (k + n - j) % n;
                or_[k] += xr[j] * hr[m] - xi[j] * hi[m];
                oi[k] += xr[j] * hi[m] + xi[j] * hr[m];
            }
        }
        (or_, oi)
    }

    #[test]
    fn circular_matches_direct() {
        let mut rng = Pcg32::seed(51);
        let n = 64;
        let xr: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let xi: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let hr: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let hi: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let planner = Planner::<f64>::new();
        let got = circular_convolve(
            &planner,
            Strategy::DualSelect,
            &SplitBuf::from_f64(&xr, &xi),
            &SplitBuf::from_f64(&hr, &hi),
        )
        .unwrap();
        let (wr, wi) = direct_circular(&xr, &xi, &hr, &hi);
        let (gr, gi) = got.to_f64();
        assert!(crate::util::metrics::rel_l2(&gr, &gi, &wr, &wi) < 1e-12);
    }

    #[test]
    fn linear_convolve_impulse_is_identity() {
        let planner = Planner::<f64>::new();
        let x = SplitBuf::from_f64(&[1.0, 2.0, 3.0], &[0.0; 3]);
        let h = SplitBuf::from_f64(&[1.0], &[0.0]);
        let y = linear_convolve(&planner, Strategy::DualSelect, &x, &h).unwrap();
        assert_eq!(y.len(), 3);
        for (i, want) in [1.0, 2.0, 3.0].iter().enumerate() {
            assert!((y.re[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn linear_convolve_matches_polynomial_multiply() {
        // (1 + 2z)(3 + 4z) = 3 + 10z + 8z²
        let planner = Planner::<f64>::new();
        let x = SplitBuf::from_f64(&[1.0, 2.0], &[0.0; 2]);
        let h = SplitBuf::from_f64(&[3.0, 4.0], &[0.0; 2]);
        let y = linear_convolve(&planner, Strategy::DualSelect, &x, &h).unwrap();
        assert_eq!(y.len(), 3);
        for (i, want) in [3.0, 10.0, 8.0].iter().enumerate() {
            assert!((y.re[i] - want).abs() < 1e-12, "i={i} got {}", y.re[i]);
        }
    }

    #[test]
    fn conj_multiply_is_correlation() {
        let a = SplitBuf::<f64>::from_f64(&[1.0], &[2.0]);
        let b = SplitBuf::<f64>::from_f64(&[3.0], &[-4.0]);
        let mut out = SplitBuf::zeroed(1);
        pointwise_mul_conj(&a, &b, &mut out);
        // (1+2j)·conj(3-4j) = (1+2j)(3+4j) = 3+4j+6j-8 = -5+10j
        assert_eq!(out.re[0], -5.0);
        assert_eq!(out.im[0], 10.0);
    }

    #[test]
    fn inplace_variants_match_out_of_place_bitwise() {
        let mut rng = Pcg32::seed(52);
        let n = 33;
        let a = SplitBuf::<f32>::from_f64(
            &(0..n).map(|_| rng.gaussian()).collect::<Vec<_>>(),
            &(0..n).map(|_| rng.gaussian()).collect::<Vec<_>>(),
        );
        let b = SplitBuf::<f32>::from_f64(
            &(0..n).map(|_| rng.gaussian()).collect::<Vec<_>>(),
            &(0..n).map(|_| rng.gaussian()).collect::<Vec<_>>(),
        );
        let mut want = SplitBuf::zeroed(n);
        pointwise_mul(&a, &b, &mut want);
        let mut got = a.clone();
        pointwise_mul_in(&mut got.re, &mut got.im, &b.re, &b.im);
        assert_eq!(got, want);

        let mut want_c = SplitBuf::zeroed(n);
        pointwise_mul_conj(&a, &b, &mut want_c);
        let mut got_c = a.clone();
        pointwise_mul_conj_in(&mut got_c.re, &mut got_c.im, &b.re, &b.im);
        assert_eq!(got_c, want_c);
    }

    #[test]
    fn length_mismatch_rejected() {
        let planner = Planner::<f64>::new();
        let x = SplitBuf::<f64>::zeroed(8);
        let h = SplitBuf::<f64>::zeroed(4);
        assert!(circular_convolve(&planner, Strategy::DualSelect, &x, &h).is_err());
    }
}
