//! The radix-2 butterfly kernels — the paper's §II in code.
//!
//! All kernels are generic over [`Real`] and `#[inline(always)]` so the
//! pass loops monomorphize to straight-line FMA code per precision.
//!
//! Operation counts (paper):
//! * [`standard`] — 4 mul + 6 add (10 ops, no FMA structure)
//! * [`ratio`] — exactly 6 fused multiply-adds, either path
//!
//! The ratio kernel is shared by Linzer-Feig, cosine and dual-select;
//! they differ only in the precomputed table (see [`super::twiddle`]).

use crate::precision::Real;

/// Schoolbook butterfly, eqs. (2)-(3): `A = a + Wb`, `B = a - Wb`.
#[inline(always)]
pub fn standard<T: Real>(
    ar: T,
    ai: T,
    br: T,
    bi: T,
    wr: T,
    wi: T,
) -> (T, T, T, T) {
    let tr = wr * br - wi * bi;
    let ti = wi * br + wr * bi;
    (ar + tr, ai + ti, ar - tr, ai - ti)
}

/// The 6-FMA ratio butterfly with a *runtime* path select (branchy
/// form — the compiler turns the operand swap into cmov/select).
///
/// Covers all three factorizations via the table:
/// * Linzer-Feig: `sel = false` always, `t = cot θ`, `m2 = sin θ`
/// * Cosine:      `sel = true` always, `t = tan θ`, `m2 = cos θ`
/// * Dual-select: per-twiddle `sel`, `|t| ≤ 1`
#[inline(always)]
pub fn ratio<T: Real>(
    ar: T,
    ai: T,
    br: T,
    bi: T,
    m1: T,
    m2: T,
    t: T,
    sel: bool,
) -> (T, T, T, T) {
    let (u, v) = if sel { (br, bi) } else { (bi, br) };
    let s1 = t.mul_add(-v, u); // FMA 1: u - t·v
    let s2 = t.mul_add(u, v); //  FMA 2: v + t·u
    let a_r = m1.mul_add(s1, ar); // FMA 3
    let b_r = (-m1).mul_add(s1, ar); // FMA 4
    let a_i = m2.mul_add(s2, ai); // FMA 5
    let b_i = (-m2).mul_add(s2, ai); // FMA 6
    (a_r, a_i, b_r, b_i)
}

/// Twiddle-only multiply `W·b` in ratio form (2 FMA + 2 mul) — the
/// building block the radix-4 kernel reuses per twiddle factor
/// (paper §VI: "each twiddle multiplication can independently select
/// the min-ratio path").
#[inline(always)]
pub fn ratio_twiddle_mul<T: Real>(br: T, bi: T, m1: T, m2: T, t: T, sel: bool) -> (T, T) {
    let (u, v) = if sel { (br, bi) } else { (bi, br) };
    let s1 = t.mul_add(-v, u);
    let s2 = t.mul_add(u, v);
    (m1 * s1, m2 * s2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::twiddle::{pass_angles, ratio_table};
    use crate::fft::{Direction, Strategy};
    use crate::precision::{Bf16, Real, F16};
    use crate::util::prng::Pcg32;

    /// f64 oracle straight from the definition A = a + W b, B = a - W b.
    fn oracle(ar: f64, ai: f64, br: f64, bi: f64, theta: f64) -> (f64, f64, f64, f64) {
        let (wr, wi) = (theta.cos(), theta.sin());
        let tr = wr * br - wi * bi;
        let ti = wi * br + wr * bi;
        (ar + tr, ai + ti, ar - tr, ai - ti)
    }

    #[test]
    fn standard_matches_definition_f64() {
        let mut rng = Pcg32::seed(10);
        for k in 0..512usize {
            let theta = -2.0 * core::f64::consts::PI * k as f64 / 1024.0;
            let (ar, ai, br, bi) = (rng.gaussian(), rng.gaussian(), rng.gaussian(), rng.gaussian());
            let got = standard(ar, ai, br, bi, theta.cos(), theta.sin());
            let want = oracle(ar, ai, br, bi, theta);
            assert!((got.0 - want.0).abs() < 1e-14);
            assert!((got.1 - want.1).abs() < 1e-14);
            assert!((got.2 - want.2).abs() < 1e-14);
            assert!((got.3 - want.3).abs() < 1e-14);
        }
    }

    /// All ratio-table strategies agree with the oracle in f64 away from
    /// their singular angles; dual-select agrees everywhere.
    #[test]
    fn ratio_strategies_match_oracle_f64() {
        let n = 1024usize;
        let angles = pass_angles(n, 9, Direction::Forward); // all k in [0, 512)
        let mut rng = Pcg32::seed(11);
        for strategy in [Strategy::LinzerFeig, Strategy::Cosine, Strategy::DualSelect] {
            let tab = ratio_table::<f64>(&angles, strategy);
            for (j, &theta) in angles.iter().enumerate() {
                let (ar, ai, br, bi) =
                    (rng.gaussian(), rng.gaussian(), rng.gaussian(), rng.gaussian());
                let got = ratio(ar, ai, br, bi, tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j]);
                let want = oracle(ar, ai, br, bi, theta);
                // Tolerance: clamped entries carry O(eps_clamp) error.
                let tol = match strategy {
                    Strategy::DualSelect => 1e-13,
                    _ => 1e-5,
                };
                for (g, w) in [got.0, got.1, got.2, got.3]
                    .iter()
                    .zip([want.0, want.1, want.2, want.3].iter())
                {
                    assert!(
                        (g - w).abs() < tol,
                        "{strategy:?} j={j} theta={theta}: {g} vs {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn dual_select_exact_at_w0_where_lf_is_not() {
        // θ = 0: W = 1, butterfly is a trivial add/sub. Dual-select
        // (cosine path, t = 0, m = 1) is *exact*; clamped LF injects
        // ~1e-7 error.
        let tab_dual = ratio_table::<f64>(&[0.0], Strategy::DualSelect);
        let tab_lf = ratio_table::<f64>(&[0.0], Strategy::LinzerFeig);
        let (ar, ai, br, bi) = (0.3, -0.7, 1.1, 0.9);
        let d = ratio(ar, ai, br, bi, tab_dual.m1[0], tab_dual.m2[0], tab_dual.t[0], tab_dual.sel[0]);
        assert_eq!(d, (ar + br, ai + bi, ar - br, ai - bi)); // bit-exact
        let l = ratio(ar, ai, br, bi, tab_lf.m1[0], tab_lf.m2[0], tab_lf.t[0], tab_lf.sel[0]);
        assert!((l.0 - (ar + br)).abs() > 1e-9); // clamp damage visible
    }

    #[test]
    fn six_fma_paths_identical_cost_structure() {
        // Both paths execute the same instruction sequence; verify the
        // two paths produce mirrored results for mirrored tables.
        let theta = -core::f64::consts::FRAC_PI_4; // |cos| == |sin|: boundary
        let tab = ratio_table::<f64>(&[theta], Strategy::DualSelect);
        assert!(tab.sel[0]); // ties go to the cosine path (>=)
        assert!((tab.t[0].abs() - 1.0).abs() < 1e-15);
        let got = ratio(1.0, 2.0, 3.0, 4.0, tab.m1[0], tab.m2[0], tab.t[0], tab.sel[0]);
        let want = oracle(1.0, 2.0, 3.0, 4.0, theta);
        assert!((got.0 - want.0).abs() < 1e-14);
        assert!((got.3 - want.3).abs() < 1e-14);
    }

    /// Per-butterfly fp16 error: dual-select stays O(eps), LF's clamped
    /// W^0 entry destroys the result (ratio 1e7 -> inf in fp16).
    #[test]
    fn fp16_per_butterfly_error_bound() {
        let mut rng = Pcg32::seed(12);
        let n = 1024usize;
        let angles = pass_angles(n, 9, Direction::Forward);
        let tab = ratio_table::<F16>(&angles, Strategy::DualSelect);
        let mut worst = 0.0f64;
        for (j, &theta) in angles.iter().enumerate() {
            let (ar, ai, br, bi) =
                (rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0));
            let a16 = |x: f64| F16::from_f64(x);
            let got = ratio(
                a16(ar), a16(ai), a16(br), a16(bi),
                tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j],
            );
            let want = oracle(
                a16(ar).to_f64(), a16(ai).to_f64(), a16(br).to_f64(), a16(bi).to_f64(),
                theta,
            );
            // Eq. (10) normalizes by the input magnitude; the output
            // FMAs round relative to |a| + |Wb|, so use both norms.
            let scale = (ar * ar + ai * ai).sqrt() + (br * br + bi * bi).sqrt();
            for (g, w) in [got.0, got.1, got.2, got.3].iter().zip([want.0, want.1, want.2, want.3]) {
                worst = worst.max((g.to_f64() - w).abs() / scale.max(1e-6));
            }
        }
        // Eq. (10): δ < C·|t|·eps·||b|| with |t| ≤ 1; C ≈ 6 covers the
        // 3-FMA rounding chains + table rounding.
        assert!(worst < 6.0 * F16::EPSILON, "worst fp16 butterfly err {worst}");
    }

    #[test]
    fn ratio_twiddle_mul_matches_complex_multiply() {
        let mut rng = Pcg32::seed(13);
        let angles = pass_angles(256, 7, Direction::Forward);
        let tab = ratio_table::<f64>(&angles, Strategy::DualSelect);
        for (j, &theta) in angles.iter().enumerate() {
            let (br, bi) = (rng.gaussian(), rng.gaussian());
            let (gr, gi) = ratio_twiddle_mul(br, bi, tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j]);
            let wr = theta.cos() * br - theta.sin() * bi;
            let wi = theta.sin() * br + theta.cos() * bi;
            assert!((gr - wr).abs() < 1e-13, "j={j}");
            assert!((gi - wi).abs() < 1e-13, "j={j}");
        }
    }

    #[test]
    fn works_in_bf16_too() {
        let angles = pass_angles(64, 5, Direction::Forward);
        let tab = ratio_table::<Bf16>(&angles, Strategy::DualSelect);
        let x = Bf16::from_f64(0.5);
        for j in 0..angles.len() {
            let got = ratio(x, x, x, x, tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j]);
            let want = oracle(0.5, 0.5, 0.5, 0.5, angles[j]);
            assert!((got.0.to_f64() - want.0).abs() < 0.03, "j={j}");
        }
    }
}
