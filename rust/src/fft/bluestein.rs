//! Bluestein (chirp-Z) FFT: arbitrary-size DFT via three power-of-two
//! dual-select FFTs — extends the paper's bounded-ratio butterflies to
//! any length.
//!
//! Identity: with `w_k = e^{-jπk²/n}` (the quadratic chirp),
//! `X_k = w_k · Σ_j x_j w_j · conj(w)_{k-j}` — a linear convolution of
//! `x·w` with `conj(w)`, computed on a power-of-two grid ≥ 2n-1 using
//! the [`super::plan`] machinery.  Every inner transform uses the
//! selected strategy's tables, so for dual-select Theorem 1's |t| ≤ 1
//! bound covers the whole pipeline.
//!
//! The plan owns its inner power-of-two plans (built once in `new`),
//! so executing needs no planner and the type slots behind the
//! [`super::Transform`] facade like every other plan.  The facade
//! auto-routes non-power-of-two [`super::PlanSpec`] sizes here.

use crate::precision::{Real, SplitBuf};

use super::api::Scratch;
use super::plan::Plan;
use super::{Direction, FftError, FftResult, Strategy};

/// Precomputed Bluestein plan for arbitrary `n >= 1`.
#[derive(Debug)]
pub struct BluesteinPlan<T: Real> {
    pub n: usize,
    /// Power-of-two convolution grid (>= 2n-1).
    pub m: usize,
    strategy: Strategy,
    direction: Direction,
    /// Chirp w_k (length n), in f64 for table fidelity.
    chirp: Vec<(f64, f64)>,
    /// FFT of the zero-padded conjugate chirp kernel (working precision).
    kernel_spec: SplitBuf<T>,
    /// m-point forward / inverse plans for the convolution.
    fwd: Plan<T>,
    inv: Plan<T>,
}

impl<T: Real> BluesteinPlan<T> {
    pub fn new(n: usize, strategy: Strategy, direction: Direction) -> FftResult<Self> {
        if n == 0 {
            return Err(FftError::InvalidSize { n, reason: "Bluestein size must be >= 1" });
        }
        let m = (2 * n - 1).next_power_of_two().max(2);
        let fwd = Plan::new(m, strategy, Direction::Forward)?;
        let inv = Plan::new(m, strategy, Direction::Inverse)?;
        let sign = direction.sign();

        // w_k = e^{sign·jπk²/n}, with k² reduced mod 2n for accuracy.
        let chirp: Vec<(f64, f64)> = (0..n)
            .map(|k| {
                let e = (k * k) % (2 * n);
                let theta = sign * core::f64::consts::PI * e as f64 / n as f64;
                (theta.cos(), theta.sin())
            })
            .collect();

        // Kernel b_j = conj(w_j) placed at j and m-j (circular symmetry).
        let mut ker = SplitBuf::<T>::zeroed(m);
        for j in 0..n {
            let (c, s) = chirp[j];
            ker.re[j] = T::from_f64(c);
            ker.im[j] = T::from_f64(-s);
            if j != 0 {
                ker.re[m - j] = T::from_f64(c);
                ker.im[m - j] = T::from_f64(-s);
            }
        }
        let mut scratch = SplitBuf::zeroed(m);
        fwd.execute(&mut ker, &mut scratch);

        Ok(BluesteinPlan { n, m, strategy, direction, chirp, kernel_spec: ker, fwd, inv })
    }

    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    pub fn direction(&self) -> Direction {
        self.direction
    }

    /// Slice core: transform one planar frame in place, drawing the
    /// two m-sized working buffers from the pooled `scratch` (no heap
    /// allocation once the pool is warm).  Arithmetic is identical to
    /// [`BluesteinPlan::transform`].
    pub fn execute_in(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        let n = self.n;
        assert_eq!(re.len(), n, "buffer length != plan size");
        assert_eq!(im.len(), n, "buffer length != plan size");
        // a_j = x_j · w_j, zero-padded to m.
        let mut a = scratch.take_zeroed(self.m);
        for j in 0..n {
            let (c, s) = self.chirp[j];
            let (wc, ws) = (T::from_f64(c), T::from_f64(s));
            a.re[j] = re[j] * wc - im[j] * ws;
            a.im[j] = im[j].mul_add(wc, re[j] * ws);
        }
        let mut work = scratch.take(self.m);
        super::stockham::execute_in(&self.fwd, &mut a.re, &mut a.im, &mut work.re, &mut work.im);

        // Pointwise multiply with the precomputed kernel spectrum,
        // in place, then convolve back.
        super::convolve::pointwise_mul_in(
            &mut a.re,
            &mut a.im,
            &self.kernel_spec.re,
            &self.kernel_spec.im,
        );
        super::stockham::execute_in(&self.inv, &mut a.re, &mut a.im, &mut work.re, &mut work.im);

        // X_k = w_k · y_k, plus 1/n for the inverse direction.  The
        // frame's input values were consumed building `a`, so writing
        // over it here is safe.
        let scale = if self.direction == Direction::Inverse {
            1.0 / n as f64
        } else {
            1.0
        };
        for k in 0..n {
            let (c, s) = self.chirp[k];
            let (wc, ws) = (T::from_f64(c * scale), T::from_f64(s * scale));
            re[k] = a.re[k] * wc - a.im[k] * ws;
            im[k] = a.im[k].mul_add(wc, a.re[k] * ws);
        }
        scratch.put(work);
        scratch.put(a);
    }

    /// Transform a length-n split signal (out-of-place, allocating —
    /// the batch path uses [`BluesteinPlan::execute_in`]).
    pub fn transform(&self, x: &SplitBuf<T>) -> SplitBuf<T> {
        let mut out = x.clone();
        let mut scratch = Scratch::new();
        self.execute_in(&mut out.re, &mut out.im, &mut scratch);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    fn run(n: usize, seed: u64) -> f64 {
        let mut rng = Pcg32::seed(seed);
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = BluesteinPlan::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let out = plan.transform(&SplitBuf::from_f64(&re, &im));
        let (wr, wi) = dft::naive_dft(&re, &im, false);
        let (gr, gi) = out.to_f64();
        rel_l2(&gr, &gi, &wr, &wi)
    }

    #[test]
    fn arbitrary_sizes_match_dft() {
        for n in [1usize, 2, 3, 5, 7, 12, 17, 100, 127, 360] {
            let err = run(n, n as u64);
            assert!(err < 1e-10, "n={n} err={err:.3e}");
        }
    }

    #[test]
    fn power_of_two_agrees_with_stockham() {
        let n = 64;
        let mut rng = Pcg32::seed(5);
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let bp = BluesteinPlan::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let out = bp.transform(&SplitBuf::from_f64(&re, &im));
        let st = super::super::Plan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let mut buf = SplitBuf::from_f64(&re, &im);
        st.execute_alloc(&mut buf);
        let (br, bi) = out.to_f64();
        let (sr, si) = buf.to_f64();
        assert!(rel_l2(&br, &bi, &sr, &si) < 1e-11);
    }

    #[test]
    fn inverse_roundtrip_arbitrary_size() {
        let n = 53; // prime
        let mut rng = Pcg32::seed(6);
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let fwd = BluesteinPlan::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let inv = BluesteinPlan::new(n, Strategy::DualSelect, Direction::Inverse).unwrap();
        let mid = fwd.transform(&SplitBuf::from_f64(&re, &im));
        let back = inv.transform(&mid);
        let (gr, gi) = back.to_f64();
        assert!(rel_l2(&gr, &gi, &re, &im) < 1e-11);
    }

    #[test]
    fn f32_accuracy_reasonable() {
        let n = 100;
        let mut rng = Pcg32::seed(7);
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = BluesteinPlan::<f32>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let out = plan.transform(&SplitBuf::from_f64(&re, &im));
        let (wr, wi) = dft::naive_dft(&re, &im, false);
        let (gr, gi) = out.to_f64();
        assert!(rel_l2(&gr, &gi, &wr, &wi) < 1e-4);
    }

    #[test]
    fn rejects_zero_size() {
        assert_eq!(
            BluesteinPlan::<f64>::new(0, Strategy::DualSelect, Direction::Forward).unwrap_err(),
            FftError::InvalidSize { n: 0, reason: "Bluestein size must be >= 1" }
        );
    }
}
