//! The FFT core: all four butterfly strategies from the paper, a
//! generic-precision radix-2 Stockham autosort transform, an in-place
//! DIT baseline, a radix-4 variant (paper §VI generality), real-input
//! transforms, Bluestein for arbitrary sizes, FFT convolution — and
//! the [`api`] facade (typed [`FftError`], the [`Transform`] trait,
//! the [`PlanSpec`] builder and the [`Planner`] cache) that fronts
//! all of them.
//!
//! Strategy cheat sheet (paper Table I, N = 1024):
//!
//! | strategy                   | ratio       | \|t\|max | singular |
//! |----------------------------|-------------|----------|----------|
//! | [`Strategy::Standard`]     | —           | —        | 0        |
//! | [`Strategy::LinzerFeig`]   | cot θ       | 163.0*   | 1 (W^0)  |
//! | [`Strategy::Cosine`]       | tan θ       | >1e16    | 0 (near) |
//! | [`Strategy::DualSelect`]   | min of both | **1.0**  | **0**    |
//!
//! *after excluding the clamped W^0 entry; the clamp itself stores 1e7.

pub mod api;
pub mod bluestein;
pub mod butterfly;
pub mod convolve;
pub mod dit;
pub mod plan;
pub mod radix4;
pub mod real_fft;
pub mod stockham;
pub mod twiddle;

pub use api::{
    Algorithm, AnyArena, AnyArenaPool, AnyPlanner, AnyScratch, AnyTransform, ArenaPool, DType,
    FftError, FftResult, FrameArena, FrameBatch, FrameBatchMut, PlanSpec, Planner, RealTransform,
    Scratch, Transform,
};
pub use plan::Plan;

use core::fmt;
use core::str::FromStr;

/// Butterfly factorization strategy (the paper's three contenders plus
/// the unfactorized baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Strategy {
    /// 10-op schoolbook butterfly (4 mul + 6 add), eqs. (2)-(3).
    Standard,
    /// Linzer-Feig 6-FMA, ratio cot θ, singular at W^0 — clamped with
    /// ε=1e-7 per standard practice (what the paper criticizes).
    LinzerFeig,
    /// Cosine 6-FMA, ratio tan θ, singular at W^{N/4} — clamped.
    Cosine,
    /// The paper's dual-select: per-twiddle min-ratio choice, |t| ≤ 1,
    /// no clamping ever needed.
    DualSelect,
}

impl Strategy {
    pub const ALL: [Strategy; 4] = [
        Strategy::Standard,
        Strategy::LinzerFeig,
        Strategy::Cosine,
        Strategy::DualSelect,
    ];

    /// Short name used by the CLI, manifests and reports.
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Standard => "standard",
            Strategy::LinzerFeig => "lf",
            Strategy::Cosine => "cos",
            Strategy::DualSelect => "dual",
        }
    }

    /// Human-readable label used in paper-style tables.
    pub fn label(self) -> &'static str {
        match self {
            Strategy::Standard => "Standard (10 op)",
            Strategy::LinzerFeig => "Linzer-Feig (/sin)",
            Strategy::Cosine => "Cosine (/cos)",
            Strategy::DualSelect => "Dual-Select (ours)",
        }
    }
}

impl FromStr for Strategy {
    type Err = FftError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "standard" | "std" => Ok(Strategy::Standard),
            "lf" | "linzer-feig" | "sin" => Ok(Strategy::LinzerFeig),
            "cos" | "cosine" => Ok(Strategy::Cosine),
            "dual" | "dual-select" => Ok(Strategy::DualSelect),
            other => Err(FftError::UnknownStrategy(other.to_string())),
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A per-request strategy selection: either an explicit [`Strategy`]
/// or `Auto`, which defers the choice to loaded tuning wisdom
/// ([`crate::tune::Wisdom`]) at admission.  `Auto` is resolved to a
/// concrete strategy *before* a request enters the batcher (so
/// [`crate::coordinator::PlanKey`]s stay concrete and a tuned request
/// batches with — and is bit-identical to — an explicit one); with no
/// wisdom entry it falls back to the server's default strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StrategyChoice {
    /// Resolve through tuning wisdom; fall back to the default.
    Auto,
    /// Use exactly this strategy.
    Explicit(Strategy),
}

impl StrategyChoice {
    /// Short name used by the CLI and reports ("auto", or the
    /// underlying strategy's name).
    pub fn name(self) -> &'static str {
        match self {
            StrategyChoice::Auto => "auto",
            StrategyChoice::Explicit(s) => s.name(),
        }
    }

    /// The concrete strategy, if one was chosen explicitly.
    pub fn explicit(self) -> Option<Strategy> {
        match self {
            StrategyChoice::Auto => None,
            StrategyChoice::Explicit(s) => Some(s),
        }
    }

    /// Resolve against an optional tuned choice, else the default.
    pub fn resolve_with(self, tuned: Option<Strategy>, default: Strategy) -> Strategy {
        match self {
            StrategyChoice::Explicit(s) => s,
            StrategyChoice::Auto => tuned.unwrap_or(default),
        }
    }
}

impl From<Strategy> for StrategyChoice {
    fn from(s: Strategy) -> Self {
        StrategyChoice::Explicit(s)
    }
}

impl FromStr for StrategyChoice {
    type Err = FftError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(StrategyChoice::Auto),
            other => other.parse::<Strategy>().map(StrategyChoice::Explicit),
        }
    }
}

impl fmt::Display for StrategyChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Transform direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Direction {
    Forward,
    Inverse,
}

impl Direction {
    /// Twiddle angle sign: e^{sign * j 2π k/N}.
    #[inline]
    pub fn sign(self) -> f64 {
        match self {
            Direction::Forward => -1.0,
            Direction::Inverse => 1.0,
        }
    }
}

/// `log2(n)` for power-of-two `n`, or [`FftError::NonPowerOfTwo`].
pub fn log2_exact(n: usize) -> FftResult<u32> {
    if n >= 2 && n.is_power_of_two() {
        Ok(n.trailing_zeros())
    } else {
        Err(FftError::NonPowerOfTwo { n })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(s.name().parse::<Strategy>().unwrap(), s);
        }
        assert!("bogus".parse::<Strategy>().is_err());
    }

    #[test]
    fn strategy_choice_parses_auto_and_delegates() {
        assert_eq!("auto".parse::<StrategyChoice>().unwrap(), StrategyChoice::Auto);
        for s in Strategy::ALL {
            let c: StrategyChoice = s.name().parse().unwrap();
            assert_eq!(c, StrategyChoice::Explicit(s));
            assert_eq!(c.name(), s.name());
            assert_eq!(c.explicit(), Some(s));
            assert_eq!(StrategyChoice::from(s), c);
        }
        assert_eq!(StrategyChoice::Auto.explicit(), None);
        assert!("bogus".parse::<StrategyChoice>().is_err());
    }

    #[test]
    fn strategy_choice_resolution_order() {
        let auto = StrategyChoice::Auto;
        // Wisdom entry wins over the default...
        assert_eq!(
            auto.resolve_with(Some(Strategy::Cosine), Strategy::DualSelect),
            Strategy::Cosine
        );
        // ...no entry falls back to the default...
        assert_eq!(auto.resolve_with(None, Strategy::DualSelect), Strategy::DualSelect);
        // ...and an explicit choice ignores both.
        let explicit = StrategyChoice::Explicit(Strategy::LinzerFeig);
        assert_eq!(
            explicit.resolve_with(Some(Strategy::Cosine), Strategy::DualSelect),
            Strategy::LinzerFeig
        );
    }

    #[test]
    fn log2_exact_accepts_powers_of_two() {
        assert_eq!(log2_exact(2), Ok(1));
        assert_eq!(log2_exact(1024), Ok(10));
        assert!(log2_exact(0).is_err());
        assert!(log2_exact(1).is_err());
        assert!(log2_exact(768).is_err());
    }

    #[test]
    fn direction_signs() {
        assert_eq!(Direction::Forward.sign(), -1.0);
        assert_eq!(Direction::Inverse.sign(), 1.0);
    }
}
