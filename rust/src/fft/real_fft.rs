//! Real-input FFT via the N/2 complex packing trick.
//!
//! Pack x[2k] + j·x[2k+1], run an N/2-point complex FFT (any strategy),
//! then untangle even/odd spectra and combine with one final twiddle
//! multiply (done in dual-select ratio form, naturally).  Returns the
//! N/2+1 non-redundant bins of the Hermitian spectrum.

use crate::precision::{Real, SplitBuf};

use super::plan::Plan;
use super::{Direction, Strategy};

/// Real-to-complex forward FFT plan for even `n`.
#[derive(Debug)]
pub struct RealFftPlan<T: Real> {
    pub n: usize,
    inner: Plan<T>,
    /// Untangle twiddles e^{-2πik/n} for k in [0, n/2], in f64 (applied
    /// in working precision at execute time).
    tw: Vec<(f64, f64)>,
}

impl<T: Real> RealFftPlan<T> {
    pub fn new(n: usize, strategy: Strategy) -> Result<Self, String> {
        if n < 4 || n % 2 != 0 {
            return Err(format!("real FFT size must be even and >= 4, got {n}"));
        }
        let inner = Plan::new(n / 2, strategy, Direction::Forward)?;
        let tw = (0..=n / 2)
            .map(|k| {
                let theta = -2.0 * core::f64::consts::PI * k as f64 / n as f64;
                (theta.cos(), theta.sin())
            })
            .collect();
        Ok(RealFftPlan { n, inner, tw })
    }

    /// Transform a length-n real signal into n/2+1 spectrum bins.
    pub fn execute(&self, x: &[T]) -> SplitBuf<T> {
        let n = self.n;
        assert_eq!(x.len(), n);
        let half = n / 2;

        // Pack even/odd samples as a complex signal.
        let mut buf = SplitBuf::<T>::zeroed(half);
        for k in 0..half {
            buf.re[k] = x[2 * k];
            buf.im[k] = x[2 * k + 1];
        }
        let mut scratch = SplitBuf::zeroed(half);
        self.inner.execute(&mut buf, &mut scratch);

        // Untangle: for k in [0, half], with Z the packed spectrum,
        //   E[k] = (Z[k] + conj(Z[half-k])) / 2        (even samples)
        //   O[k] = (Z[k] - conj(Z[half-k])) / (2j)     (odd samples)
        //   X[k] = E[k] + e^{-2πik/n}·O[k]
        let mut out = SplitBuf::<T>::zeroed(half + 1);
        let h = T::from_f64(0.5);
        for k in 0..=half {
            let (zr_k, zi_k, zr_m, zi_m) = {
                let km = (half - k) % half;
                let kk = k % half;
                (buf.re[kk], buf.im[kk], buf.re[km], buf.im[km])
            };
            let er = (zr_k + zr_m) * h;
            let ei = (zi_k - zi_m) * h;
            let or_ = (zi_k + zi_m) * h;
            let oi = (zr_m - zr_k) * h;
            // Twiddle multiply (f64 table rounded into T on the fly; the
            // table is small — n/2+1 entries).
            let (c, s) = self.tw[k];
            let wc = T::from_f64(c);
            let ws = T::from_f64(s);
            let tr = wc * or_ - ws * oi;
            let ti = ws.mul_add(or_, wc * oi);
            out.re[k] = er + tr;
            out.im[k] = ei + ti;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    #[test]
    fn real_fft_matches_dft() {
        let mut rng = Pcg32::seed(41);
        for n in [4usize, 8, 64, 256, 1024] {
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let plan = RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap();
            let xt: Vec<f64> = x.clone();
            let out = plan.execute(&xt);
            let (wr, wi) = dft::naive_dft(&x, &vec![0.0; n], false);
            let (gr, gi) = out.to_f64();
            assert!(
                rel_l2(&gr, &gi, &wr[..=n / 2].to_vec(), &wi[..=n / 2].to_vec()) < 1e-12,
                "n={n}"
            );
        }
    }

    #[test]
    fn dc_and_nyquist_are_real() {
        let mut rng = Pcg32::seed(42);
        let n = 128;
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap();
        let out = plan.execute(&x);
        assert!(out.im[0].abs() < 1e-12);
        assert!(out.im[n / 2].abs() < 1e-12);
        // DC = sum of samples
        assert!((out.re[0] - x.iter().sum::<f64>()).abs() < 1e-10);
    }

    #[test]
    fn rejects_odd_sizes() {
        assert!(RealFftPlan::<f64>::new(6, Strategy::DualSelect).is_err()); // n/2 = 3 not pow2
        assert!(RealFftPlan::<f64>::new(3, Strategy::DualSelect).is_err());
    }

    #[test]
    fn real_fft_f32_accuracy() {
        let mut rng = Pcg32::seed(43);
        let n = 512;
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = RealFftPlan::<f32>::new(n, Strategy::DualSelect).unwrap();
        let xt: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let out = plan.execute(&xt);
        let (wr, wi) = dft::naive_dft(&x, &vec![0.0; n], false);
        let (gr, gi) = out.to_f64();
        assert!(rel_l2(&gr, &gi, &wr[..=n / 2].to_vec(), &wi[..=n / 2].to_vec()) < 1e-5);
    }
}
