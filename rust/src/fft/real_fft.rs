//! Real-input FFT via the N/2 complex packing trick — both directions.
//!
//! Forward (r2c): pack x[2k] + j·x[2k+1], run an N/2-point complex FFT
//! (any strategy), then untangle even/odd spectra and combine with one
//! final twiddle multiply.  Returns the N/2+1 non-redundant bins of
//! the Hermitian spectrum.
//!
//! Inverse (c2r): the exact algebraic inverse — re-tangle the N/2+1
//! Hermitian bins into the packed spectrum Z, run an N/2-point inverse
//! complex FFT, and deinterleave the real/imag lanes into the even/odd
//! samples.  `IFFT_real(FFT_real(x)) == x` up to rounding.
//!
//! Behind the facade both directions are reachable as
//! `PlanSpec::new(n).real_input()` (+ `.inverse()`), executing with
//! full-spectrum buffer semantics (see [`super::RealTransform`]).

use crate::precision::{Real, SplitBuf};

use super::api::Scratch;
use super::plan::Plan;
use super::{Direction, FftError, FftResult, Strategy};

/// Real FFT plan for even `n` (with `n/2` a power of two): r2c forward
/// and c2r inverse over the same precomputed half-size tables.
#[derive(Debug)]
pub struct RealFftPlan<T: Real> {
    pub n: usize,
    pub strategy: Strategy,
    /// Half-size forward complex plan (r2c path).
    fwd: Plan<T>,
    /// Half-size inverse complex plan (c2r path).
    inv: Plan<T>,
    /// Untangle twiddles e^{-2πik/n} for k in [0, n/2], in f64 (applied
    /// in working precision at execute time).
    tw: Vec<(f64, f64)>,
}

impl<T: Real> RealFftPlan<T> {
    pub fn new(n: usize, strategy: Strategy) -> FftResult<Self> {
        // Validate the caller's n in full here: letting the inner
        // half-size plan reject n/2 would surface a size the caller
        // never asked for.
        if n < 4 || n % 2 != 0 || !(n / 2).is_power_of_two() {
            return Err(FftError::InvalidSize {
                n,
                reason: "real FFT size must be >= 4 with n/2 a power of two",
            });
        }
        let fwd = Plan::new(n / 2, strategy, Direction::Forward)?;
        let inv = Plan::new(n / 2, strategy, Direction::Inverse)?;
        let tw = (0..=n / 2)
            .map(|k| {
                let theta = -2.0 * core::f64::consts::PI * k as f64 / n as f64;
                (theta.cos(), theta.sin())
            })
            .collect();
        Ok(RealFftPlan { n, strategy, fwd, inv, tw })
    }

    /// Slice core, forward, full-spectrum semantics: the frame's `re`
    /// plane holds the length-n real signal (`im` is ignored); on
    /// return the frame holds the full complex spectrum — bins
    /// `0..=n/2` computed by the half-size packing trick, the rest
    /// filled by Hermitian symmetry.  Working buffers (two half-size)
    /// come from the pooled `scratch`.  Arithmetic is identical to
    /// [`RealFftPlan::execute`].
    pub fn forward_full(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        let n = self.n;
        assert_eq!(re.len(), n, "buffer length != plan size");
        assert_eq!(im.len(), n, "buffer length != plan size");
        let half = n / 2;

        // Pack even/odd samples as a complex signal.
        let mut packed = scratch.take(half);
        for k in 0..half {
            packed.re[k] = re[2 * k];
            packed.im[k] = re[2 * k + 1];
        }
        let mut work = scratch.take(half);
        super::stockham::execute_in(
            &self.fwd,
            &mut packed.re,
            &mut packed.im,
            &mut work.re,
            &mut work.im,
        );

        // Untangle (reads only `packed`, so writing the frame is safe):
        //   E[k] = (Z[k] + conj(Z[half-k])) / 2
        //   O[k] = (Z[k] - conj(Z[half-k])) / (2j)
        //   X[k] = E[k] + e^{-2πik/n}·O[k]
        let h = T::from_f64(0.5);
        for k in 0..=half {
            let (zr_k, zi_k, zr_m, zi_m) = {
                let km = (half - k) % half;
                let kk = k % half;
                (packed.re[kk], packed.im[kk], packed.re[km], packed.im[km])
            };
            let er = (zr_k + zr_m) * h;
            let ei = (zi_k - zi_m) * h;
            let or_ = (zi_k + zi_m) * h;
            let oi = (zr_m - zr_k) * h;
            let (c, s) = self.tw[k];
            let wc = T::from_f64(c);
            let ws = T::from_f64(s);
            let tr = wc * or_ - ws * oi;
            let ti = ws.mul_add(or_, wc * oi);
            re[k] = er + tr;
            im[k] = ei + ti;
        }
        // Hermitian extension: bins half+1..n mirror bins 1..half,
        // which were just written and are not touched again.
        for k in half + 1..n {
            re[k] = re[n - k];
            im[k] = -im[n - k];
        }
        scratch.put(work);
        scratch.put(packed);
    }

    /// Slice core, inverse, full-spectrum semantics: the frame holds a
    /// Hermitian spectrum (only bins `0..=n/2` are read); on return
    /// `re` holds the length-n real signal and `im` is zero.
    /// Arithmetic is identical to [`RealFftPlan::execute_inverse`].
    pub fn inverse_full(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        let n = self.n;
        assert_eq!(re.len(), n, "buffer length != plan size");
        assert_eq!(im.len(), n, "buffer length != plan size");
        let half = n / 2;

        // Re-tangle bins 0..=half into the packed spectrum Z (reads
        // the frame before any write — `packed` is separate storage).
        let mut packed = scratch.take(half);
        let h = T::from_f64(0.5);
        for k in 0..half {
            let m = half - k; // in [1, half]
            let (xr_k, xi_k) = (re[k], im[k]);
            let (xr_m, xi_m) = (re[m], im[m]);
            let er = (xr_k + xr_m) * h;
            let ei = (xi_k - xi_m) * h;
            let dr = (xr_k - xr_m) * h;
            let di = (xi_k + xi_m) * h;
            let (c, s) = self.tw[k];
            let wc = T::from_f64(c);
            let ws = T::from_f64(s);
            let or_ = wc.mul_add(dr, ws * di);
            let oi = wc.mul_add(di, -(ws * dr));
            packed.re[k] = er - oi;
            packed.im[k] = ei + or_;
        }
        let mut work = scratch.take(half);
        super::stockham::execute_in(
            &self.inv,
            &mut packed.re,
            &mut packed.im,
            &mut work.re,
            &mut work.im,
        );
        for k in 0..half {
            re[2 * k] = packed.re[k];
            re[2 * k + 1] = packed.im[k];
        }
        im.fill(T::zero());
        scratch.put(work);
        scratch.put(packed);
    }

    /// Transform a length-n real signal into n/2+1 spectrum bins.
    pub fn execute(&self, x: &[T]) -> SplitBuf<T> {
        let n = self.n;
        assert_eq!(x.len(), n);
        let half = n / 2;

        // Pack even/odd samples as a complex signal.
        let mut buf = SplitBuf::<T>::zeroed(half);
        for k in 0..half {
            buf.re[k] = x[2 * k];
            buf.im[k] = x[2 * k + 1];
        }
        let mut scratch = SplitBuf::zeroed(half);
        self.fwd.execute(&mut buf, &mut scratch);

        // Untangle: for k in [0, half], with Z the packed spectrum,
        //   E[k] = (Z[k] + conj(Z[half-k])) / 2        (even samples)
        //   O[k] = (Z[k] - conj(Z[half-k])) / (2j)     (odd samples)
        //   X[k] = E[k] + e^{-2πik/n}·O[k]
        let mut out = SplitBuf::<T>::zeroed(half + 1);
        let h = T::from_f64(0.5);
        for k in 0..=half {
            let (zr_k, zi_k, zr_m, zi_m) = {
                let km = (half - k) % half;
                let kk = k % half;
                (buf.re[kk], buf.im[kk], buf.re[km], buf.im[km])
            };
            let er = (zr_k + zr_m) * h;
            let ei = (zi_k - zi_m) * h;
            let or_ = (zi_k + zi_m) * h;
            let oi = (zr_m - zr_k) * h;
            // Twiddle multiply (f64 table rounded into T on the fly; the
            // table is small — n/2+1 entries).
            let (c, s) = self.tw[k];
            let wc = T::from_f64(c);
            let ws = T::from_f64(s);
            let tr = wc * or_ - ws * oi;
            let ti = ws.mul_add(or_, wc * oi);
            out.re[k] = er + tr;
            out.im[k] = ei + ti;
        }
        out
    }

    /// Inverse (c2r): reconstruct the length-n real signal from its
    /// n/2+1 Hermitian spectrum bins.
    ///
    /// For k in [0, half), with X the given half-spectrum:
    ///   E[k] = (X[k] + conj(X[half-k])) / 2
    ///   O[k] = (X[k] - conj(X[half-k])) / 2 · e^{+2πik/n}
    ///   Z[k] = E[k] + j·O[k]
    /// then z = IFFT_{n/2}(Z) and x[2k] = Re z[k], x[2k+1] = Im z[k].
    pub fn execute_inverse(&self, spectrum: &SplitBuf<T>) -> FftResult<Vec<T>> {
        let n = self.n;
        let half = n / 2;
        if spectrum.len() != half + 1 {
            return Err(FftError::LengthMismatch { expected: half + 1, got: spectrum.len() });
        }

        let mut buf = SplitBuf::<T>::zeroed(half);
        let h = T::from_f64(0.5);
        for k in 0..half {
            let m = half - k; // in [1, half]
            let (xr_k, xi_k) = (spectrum.re[k], spectrum.im[k]);
            let (xr_m, xi_m) = (spectrum.re[m], spectrum.im[m]);
            // E[k] = (X[k] + conj(X[m]))/2, D[k] = (X[k] - conj(X[m]))/2.
            let er = (xr_k + xr_m) * h;
            let ei = (xi_k - xi_m) * h;
            let dr = (xr_k - xr_m) * h;
            let di = (xi_k + xi_m) * h;
            // O[k] = D[k] · conj(W^k) with W^k = e^{-2πik/n} = (c, s).
            let (c, s) = self.tw[k];
            let wc = T::from_f64(c);
            let ws = T::from_f64(s);
            let or_ = wc.mul_add(dr, ws * di);
            let oi = wc.mul_add(di, -(ws * dr));
            // Z[k] = E[k] + j·O[k].
            buf.re[k] = er - oi;
            buf.im[k] = ei + or_;
        }
        let mut scratch = SplitBuf::zeroed(half);
        self.inv.execute(&mut buf, &mut scratch);

        let mut x = vec![T::zero(); n];
        for k in 0..half {
            x[2 * k] = buf.re[k];
            x[2 * k + 1] = buf.im[k];
        }
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    #[test]
    fn real_fft_matches_dft() {
        let mut rng = Pcg32::seed(41);
        for n in [4usize, 8, 64, 256, 1024] {
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let plan = RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap();
            let xt: Vec<f64> = x.clone();
            let out = plan.execute(&xt);
            let (wr, wi) = dft::naive_dft(&x, &vec![0.0; n], false);
            let (gr, gi) = out.to_f64();
            assert!(
                rel_l2(&gr, &gi, &wr[..=n / 2].to_vec(), &wi[..=n / 2].to_vec()) < 1e-12,
                "n={n}"
            );
        }
    }

    #[test]
    fn dc_and_nyquist_are_real() {
        let mut rng = Pcg32::seed(42);
        let n = 128;
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap();
        let out = plan.execute(&x);
        assert!(out.im[0].abs() < 1e-12);
        assert!(out.im[n / 2].abs() < 1e-12);
        // DC = sum of samples
        assert!((out.re[0] - x.iter().sum::<f64>()).abs() < 1e-10);
    }

    #[test]
    fn rejects_odd_sizes() {
        // n/2 = 3 not pow2: the error names the requested n, not n/2.
        assert_eq!(
            RealFftPlan::<f64>::new(6, Strategy::DualSelect).unwrap_err(),
            FftError::InvalidSize { n: 6, reason: "real FFT size must be >= 4 with n/2 a power of two" }
        );
        assert_eq!(
            RealFftPlan::<f64>::new(3, Strategy::DualSelect).unwrap_err(),
            FftError::InvalidSize { n: 3, reason: "real FFT size must be >= 4 with n/2 a power of two" }
        );
    }

    #[test]
    fn real_fft_f32_accuracy() {
        let mut rng = Pcg32::seed(43);
        let n = 512;
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = RealFftPlan::<f32>::new(n, Strategy::DualSelect).unwrap();
        let xt: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let out = plan.execute(&xt);
        let (wr, wi) = dft::naive_dft(&x, &vec![0.0; n], false);
        let (gr, gi) = out.to_f64();
        assert!(rel_l2(&gr, &gi, &wr[..=n / 2].to_vec(), &wi[..=n / 2].to_vec()) < 1e-5);
    }

    #[test]
    fn inverse_roundtrips_forward() {
        let mut rng = Pcg32::seed(44);
        for n in [4usize, 8, 64, 512, 2048] {
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let plan = RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap();
            let spec = plan.execute(&x);
            let back = plan.execute_inverse(&spec).unwrap();
            let got: Vec<f64> = back.iter().map(|v| v.to_f64()).collect();
            assert!(
                rel_l2(&got, &vec![0.0; n], &x, &vec![0.0; n]) < 1e-12,
                "n={n}"
            );
        }
    }

    #[test]
    fn inverse_matches_full_complex_ifft() {
        // c2r of a Hermitian spectrum equals the real part of the full
        // complex inverse DFT.
        let mut rng = Pcg32::seed(45);
        let n = 64;
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let (fr, fi) = dft::naive_dft(&x, &vec![0.0; n], false);
        let plan = RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap();
        let mut spec = SplitBuf::<f64>::zeroed(n / 2 + 1);
        for k in 0..=n / 2 {
            spec.re[k] = fr[k];
            spec.im[k] = fi[k];
        }
        let back = plan.execute_inverse(&spec).unwrap();
        assert!(rel_l2(&back, &vec![0.0; n], &x, &vec![0.0; n]) < 1e-12);
    }

    #[test]
    fn inverse_rejects_wrong_spectrum_length() {
        let plan = RealFftPlan::<f64>::new(64, Strategy::DualSelect).unwrap();
        let bad = SplitBuf::<f64>::zeroed(64);
        assert_eq!(
            plan.execute_inverse(&bad).unwrap_err(),
            FftError::LengthMismatch { expected: 33, got: 64 }
        );
    }

    #[test]
    fn inverse_works_in_f32() {
        let mut rng = Pcg32::seed(46);
        let n = 256;
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = RealFftPlan::<f32>::new(n, Strategy::DualSelect).unwrap();
        let xt: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let spec = plan.execute(&xt);
        let back = plan.execute_inverse(&spec).unwrap();
        let got: Vec<f64> = back.iter().map(|v| v.to_f64()).collect();
        assert!(rel_l2(&got, &vec![0.0; n], &x, &vec![0.0; n]) < 1e-5);
    }
}
