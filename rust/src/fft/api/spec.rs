//! [`PlanSpec`]: one builder for every transform kind.
//!
//! ```
//! use fmafft::fft::{Direction, DType, PlanSpec, Strategy, Transform};
//! use fmafft::precision::SplitBuf;
//!
//! // FFT of a constant is n·δ0.
//! let fft = PlanSpec::new(8).strategy(Strategy::DualSelect).build::<f32>().unwrap();
//! let mut buf = SplitBuf::<f32>::from_f64(&[1.0; 8], &[0.0; 8]);
//! fft.execute_alloc(&mut buf);
//! assert!((buf.re[0] - 8.0).abs() < 1e-3);
//!
//! // Non-power-of-two sizes auto-route instead of erroring:
//! // {2,3}-smooth composites hit the mixed-radix kernel engine,
//! // everything else goes through Bluestein.
//! let odd = PlanSpec::new(12).build::<f64>().unwrap();
//! assert_eq!(odd.len(), 12);
//! let prime = PlanSpec::new(101).build::<f64>().unwrap();
//! assert_eq!(prime.len(), 101);
//!
//! // The builder covers direction, algorithm and real input too.
//! let spec = PlanSpec::new(1024)
//!     .strategy(Strategy::DualSelect)
//!     .direction(Direction::Inverse)
//!     .radix4();
//! assert!(spec.build::<f32>().is_ok());
//!
//! // Pick the working precision at run time with the dtype-erased
//! // form (what the serving plane does).
//! let any = PlanSpec::new(8).dtype(DType::F16).build_any().unwrap();
//! assert_eq!(any.dtype(), DType::F16);
//! ```

use std::sync::Arc;

use crate::kernel::{Kernel, MixedRadixPlan};
use crate::precision::Real;

use super::super::bluestein::BluesteinPlan;
use super::super::dit::DitPlan;
use super::super::plan::Plan;
use super::super::radix4::Radix4Plan;
use super::super::real_fft::RealFftPlan;
use super::super::{Direction, Strategy};
use super::dtype::{AnyTransform, DType};
use super::error::{FftError, FftResult};
use super::transform::{RealTransform, Transform};

/// Which FFT organization executes the plan.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Algorithm {
    /// Pick automatically: Stockham radix-2 for powers of two, the
    /// mixed-radix kernel engine for composite `2^a·3^b` sizes under
    /// a ratio strategy (and for any {2,3}-smooth size when a kernel
    /// variant is explicitly requested), Bluestein (chirp-Z) for
    /// everything else.
    #[default]
    Auto,
    /// Radix-2 Stockham autosort (the tuned hot path).
    Stockham,
    /// Radix-4 Stockham (powers of four, ratio strategies only).
    Radix4,
    /// Mixed-radix 2/3/4/8 Stockham with runtime SIMD dispatch
    /// ([`crate::kernel::MixedRadixPlan`]; {2,3}-smooth sizes, ratio
    /// strategies only).
    MixedRadix,
    /// In-place Cooley-Tukey DIT with bit reversal (ablation baseline).
    Dit,
    /// Bluestein chirp-Z (any size >= 1).
    Bluestein,
}

/// A complete description of a transform: the [`super::Planner`] cache
/// key and the input to [`PlanSpec::build`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanSpec {
    pub n: usize,
    pub strategy: Strategy,
    pub direction: Direction,
    pub algorithm: Algorithm,
    /// Butterfly kernel variant for algorithms that have more than
    /// one ([`Algorithm::MixedRadix`], and [`Algorithm::Auto`] when
    /// it routes there): `Auto` resolves to SIMD where the host
    /// supports it, `Scalar`/`Simd` pin an arm.  Plans that have only
    /// scalar kernels ignore it (but it stays part of the cache key).
    pub kernel: Kernel,
    pub real_input: bool,
    /// Working precision used by [`PlanSpec::build_any`] and the
    /// dtype-erased planner cache.  The statically-typed
    /// [`PlanSpec::build`] ignores it — there `T` decides.
    pub dtype: DType,
}

impl PlanSpec {
    /// A forward, dual-select, auto-algorithm, f32 complex transform
    /// of size `n`; refine with the builder methods.
    pub fn new(n: usize) -> Self {
        PlanSpec {
            n,
            strategy: Strategy::DualSelect,
            direction: Direction::Forward,
            algorithm: Algorithm::Auto,
            kernel: Kernel::Auto,
            real_input: false,
            dtype: DType::F32,
        }
    }

    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Working precision for the dtype-erased build path.
    pub fn dtype(mut self, dtype: DType) -> Self {
        self.dtype = dtype;
        self
    }

    pub fn direction(mut self, direction: Direction) -> Self {
        self.direction = direction;
        self
    }

    pub fn forward(self) -> Self {
        self.direction(Direction::Forward)
    }

    pub fn inverse(self) -> Self {
        self.direction(Direction::Inverse)
    }

    pub fn algorithm(mut self, algorithm: Algorithm) -> Self {
        self.algorithm = algorithm;
        self
    }

    pub fn stockham(self) -> Self {
        self.algorithm(Algorithm::Stockham)
    }

    pub fn radix4(self) -> Self {
        self.algorithm(Algorithm::Radix4)
    }

    pub fn dit(self) -> Self {
        self.algorithm(Algorithm::Dit)
    }

    pub fn mixed_radix(self) -> Self {
        self.algorithm(Algorithm::MixedRadix)
    }

    pub fn bluestein(self) -> Self {
        self.algorithm(Algorithm::Bluestein)
    }

    /// Butterfly kernel variant (auto / scalar / simd) for the
    /// mixed-radix engine.
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Treat the input as real samples (in the `re` lane); see
    /// [`RealTransform`] for the exact buffer semantics.
    pub fn real_input(mut self) -> Self {
        self.real_input = true;
        self
    }

    /// Build the transform this spec describes.
    pub fn build<T: Real>(&self) -> FftResult<Box<dyn Transform<T>>> {
        if self.real_input {
            if !matches!(self.algorithm, Algorithm::Auto | Algorithm::Stockham) {
                return Err(FftError::Unsupported(
                    "real-input transforms run on the Stockham core (use Auto or Stockham)",
                ));
            }
            let plan = RealFftPlan::<T>::new(self.n, self.strategy)?;
            return Ok(Box::new(RealTransform::new(plan, self.direction)));
        }
        match self.algorithm {
            Algorithm::Auto => {
                let pow2 = self.n >= 2 && self.n.is_power_of_two();
                let ratio = self.strategy != Strategy::Standard;
                // Powers of two keep the classic radix-2 plan (its
                // serving results are pinned bit-for-bit) unless a
                // kernel variant was explicitly requested; composite
                // {2,3}-smooth sizes go to the mixed-radix engine
                // instead of the Bluestein detour; everything else —
                // other prime factors, or the standard strategy the
                // kernel engine's ratio tables cannot express — stays
                // on Bluestein/Stockham as before.
                if crate::kernel::is_23_smooth(self.n)
                    && ratio
                    && (!pow2 || self.kernel != Kernel::Auto)
                {
                    Ok(Box::new(MixedRadixPlan::<T>::with_kernel(
                        self.n,
                        self.strategy,
                        self.direction,
                        self.kernel,
                    )?))
                } else if pow2 {
                    Ok(Box::new(Plan::<T>::new(self.n, self.strategy, self.direction)?))
                } else {
                    Ok(Box::new(BluesteinPlan::<T>::new(
                        self.n,
                        self.strategy,
                        self.direction,
                    )?))
                }
            }
            Algorithm::MixedRadix => Ok(Box::new(MixedRadixPlan::<T>::with_kernel(
                self.n,
                self.strategy,
                self.direction,
                self.kernel,
            )?)),
            Algorithm::Stockham => {
                Ok(Box::new(Plan::<T>::new(self.n, self.strategy, self.direction)?))
            }
            Algorithm::Radix4 => Ok(Box::new(Radix4Plan::<T>::new(
                self.n,
                self.strategy,
                self.direction,
            )?)),
            Algorithm::Dit => {
                Ok(Box::new(DitPlan::<T>::new(self.n, self.strategy, self.direction)?))
            }
            Algorithm::Bluestein => Ok(Box::new(BluesteinPlan::<T>::new(
                self.n,
                self.strategy,
                self.direction,
            )?)),
        }
    }

    /// Build the transform this spec describes in the working
    /// precision named by `self.dtype` — the dtype-erased form the
    /// serving plane and [`super::AnyPlanner`] use.  Float arms route
    /// through [`PlanSpec::build`], so per dtype the produced
    /// transform is identical to the statically-typed one; the fixed
    /// arms build a [`crate::fixed::FixedPlan`] (Stockham-only,
    /// complex-only, dual-select-only — everything else is a typed
    /// error, never a silent fallback).
    pub fn build_any(&self) -> FftResult<AnyTransform> {
        Ok(match self.dtype {
            DType::F64 => AnyTransform::F64(Arc::from(self.build::<f64>()?)),
            DType::F32 => AnyTransform::F32(Arc::from(self.build::<f32>()?)),
            DType::Bf16 => AnyTransform::Bf16(Arc::from(self.build::<crate::precision::Bf16>()?)),
            DType::F16 => AnyTransform::F16(Arc::from(self.build::<crate::precision::F16>()?)),
            DType::I16 => AnyTransform::I16(Arc::new(self.build_fixed()?)),
            DType::I32 => AnyTransform::I32(Arc::new(self.build_fixed()?)),
        })
    }

    fn build_fixed<Q: crate::fixed::QSample>(&self) -> FftResult<crate::fixed::FixedPlan<Q>> {
        if self.real_input {
            return Err(FftError::Unsupported(
                "real-input transforms are not available in fixed point (complex frames only)",
            ));
        }
        if !matches!(self.algorithm, Algorithm::Auto | Algorithm::Stockham) {
            return Err(FftError::Unsupported(
                "fixed-point transforms run on the Stockham core (use Auto or Stockham)",
            ));
        }
        crate::fixed::FixedPlan::<Q>::new(self.n, self.strategy, self.direction)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::SplitBuf;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    #[test]
    fn auto_routes_pow2_to_stockham_tables() {
        let t = PlanSpec::new(256).build::<f64>().unwrap();
        assert_eq!(t.len(), 256);
        assert_eq!(t.strategy(), Strategy::DualSelect);
    }

    #[test]
    fn auto_routes_non_pow2_to_bluestein() {
        // The old Plan::new path errored here; the facade serves it.
        let t = PlanSpec::new(100).build::<f64>().unwrap();
        assert_eq!(t.len(), 100);
        let mut rng = Pcg32::seed(1);
        let re: Vec<f64> = (0..100).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..100).map(|_| rng.gaussian()).collect();
        let mut buf = SplitBuf::from_f64(&re, &im);
        t.execute_alloc(&mut buf);
        let (wr, wi) = crate::dft::naive_dft(&re, &im, false);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &wr, &wi) < 1e-10);
    }

    #[test]
    fn auto_routes_composite_23_smooth_to_mixed_radix() {
        // 48 = 2^4·3 used to take the Bluestein detour; now it gets a
        // direct mixed-radix plan (and the answer still matches DFT).
        for n in [12usize, 48, 96, 1536] {
            let t = PlanSpec::new(n).build::<f64>().unwrap();
            assert!(
                format!("{t:?}").contains("MixedRadixPlan"),
                "n={n} routed to {t:?}"
            );
            let mut rng = Pcg32::seed(n as u64);
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let mut buf = SplitBuf::from_f64(&re, &im);
            t.execute_alloc(&mut buf);
            let (wr, wi) = crate::dft::naive_dft(&re, &im, false);
            let (gr, gi) = buf.to_f64();
            assert!(rel_l2(&gr, &gi, &wr, &wi) < 1e-11, "n={n}");
        }
        // Powers of two keep the classic pinned plan under Kernel::Auto...
        let t = PlanSpec::new(64).build::<f64>().unwrap();
        assert!(format!("{t:?}").contains("Plan"), "{t:?}");
        assert!(!format!("{t:?}").contains("MixedRadixPlan"), "{t:?}");
        // ...but an explicit kernel request opts them into the engine.
        let t = PlanSpec::new(64).kernel(Kernel::Scalar).build::<f64>().unwrap();
        assert!(format!("{t:?}").contains("MixedRadixPlan"), "{t:?}");
        // The standard strategy has no ratio tables: composite sizes
        // stay on Bluestein.
        let t = PlanSpec::new(48).strategy(Strategy::Standard).build::<f64>().unwrap();
        assert!(format!("{t:?}").contains("BluesteinPlan"), "{t:?}");
    }

    #[test]
    fn explicit_mixed_radix_rejects_what_it_cannot_serve() {
        assert!(matches!(
            PlanSpec::new(100).mixed_radix().build::<f64>().unwrap_err(),
            FftError::InvalidSize { n: 100, .. }
        ));
        assert!(matches!(
            PlanSpec::new(48)
                .strategy(Strategy::Standard)
                .mixed_radix()
                .build::<f64>()
                .unwrap_err(),
            FftError::UnsupportedStrategy { .. }
        ));
        assert!(PlanSpec::new(48).mixed_radix().build::<f32>().is_ok());
    }

    #[test]
    fn kernel_is_part_of_the_cache_key() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(PlanSpec::new(48));
        set.insert(PlanSpec::new(48).kernel(Kernel::Auto)); // same as default
        set.insert(PlanSpec::new(48).kernel(Kernel::Scalar));
        set.insert(PlanSpec::new(48).kernel(Kernel::Simd));
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn mixed_radix_builds_in_every_float_dtype() {
        for dtype in DType::FLOATS {
            let t = PlanSpec::new(96).dtype(dtype).build_any().unwrap();
            assert_eq!(t.dtype(), dtype);
            assert_eq!(t.len(), 96);
        }
        // Fixed dtypes stay on the Stockham-only core: a composite
        // size is a typed error, never a silent fallback.
        assert!(matches!(
            PlanSpec::new(96).dtype(DType::I16).build_any().unwrap_err(),
            FftError::NonPowerOfTwo { n: 96 }
        ));
        assert!(matches!(
            PlanSpec::new(64).mixed_radix().dtype(DType::I32).build_any().unwrap_err(),
            FftError::Unsupported(_)
        ));
    }

    #[test]
    fn explicit_stockham_still_rejects_non_pow2() {
        assert_eq!(
            PlanSpec::new(100).stockham().build::<f32>().unwrap_err(),
            FftError::NonPowerOfTwo { n: 100 }
        );
    }

    #[test]
    fn radix4_requires_power_of_four_and_ratio_strategy() {
        assert!(PlanSpec::new(64).radix4().build::<f32>().is_ok());
        assert!(matches!(
            PlanSpec::new(128).radix4().build::<f32>().unwrap_err(),
            FftError::InvalidSize { n: 128, .. }
        ));
        assert!(matches!(
            PlanSpec::new(64)
                .strategy(Strategy::Standard)
                .radix4()
                .build::<f32>()
                .unwrap_err(),
            FftError::UnsupportedStrategy { .. }
        ));
    }

    #[test]
    fn real_input_builds_and_rejects_bad_sizes() {
        assert!(PlanSpec::new(256).real_input().build::<f64>().is_ok());
        // n/2 must be a power of two for the packing trick.
        assert!(PlanSpec::new(6).real_input().build::<f64>().is_err());
        assert!(matches!(
            PlanSpec::new(3).real_input().build::<f64>().unwrap_err(),
            FftError::InvalidSize { n: 3, .. }
        ));
        // Real input on the radix-4 organization is not a thing.
        assert!(matches!(
            PlanSpec::new(256).real_input().radix4().build::<f64>().unwrap_err(),
            FftError::Unsupported(_)
        ));
    }

    #[test]
    fn spec_is_a_value_type_cache_key() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(PlanSpec::new(8));
        set.insert(PlanSpec::new(8).forward());
        set.insert(PlanSpec::new(8).inverse());
        set.insert(PlanSpec::new(8).dit());
        assert_eq!(set.len(), 3);
        // The dtype is part of the key: same shape, different working
        // precision, distinct cache entries.
        set.insert(PlanSpec::new(8).dtype(DType::F16));
        set.insert(PlanSpec::new(8).dtype(DType::Bf16));
        assert_eq!(set.len(), 5);
    }

    #[test]
    fn build_any_dispatches_on_spec_dtype() {
        for dtype in DType::ALL {
            let t = PlanSpec::new(64)
                .strategy(Strategy::DualSelect)
                .dtype(dtype)
                .build_any()
                .unwrap();
            assert_eq!(t.dtype(), dtype);
            assert_eq!(t.len(), 64);
        }
        // Build errors carry through unchanged.
        assert_eq!(
            PlanSpec::new(100).stockham().dtype(DType::F16).build_any().unwrap_err(),
            FftError::NonPowerOfTwo { n: 100 }
        );
        // Every algorithm builds in every float dtype (Bluestein via
        // odd n).
        for dtype in DType::FLOATS {
            assert!(PlanSpec::new(60).dtype(dtype).build_any().is_ok());
            assert!(PlanSpec::new(64).radix4().dtype(dtype).build_any().is_ok());
            assert!(PlanSpec::new(64).dit().dtype(dtype).build_any().is_ok());
            assert!(PlanSpec::new(64).real_input().dtype(dtype).build_any().is_ok());
        }
        // Fixed dtypes are Stockham/complex/dual-select only; every
        // escape hatch is a typed error, never a fallback.
        for dtype in [DType::I16, DType::I32] {
            assert!(PlanSpec::new(64).dtype(dtype).build_any().is_ok());
            assert!(PlanSpec::new(64).stockham().dtype(dtype).build_any().is_ok());
            assert!(matches!(
                PlanSpec::new(60).dtype(dtype).build_any().unwrap_err(),
                FftError::NonPowerOfTwo { n: 60 }
            ));
            assert!(matches!(
                PlanSpec::new(64).radix4().dtype(dtype).build_any().unwrap_err(),
                FftError::Unsupported(_)
            ));
            assert!(matches!(
                PlanSpec::new(64).real_input().dtype(dtype).build_any().unwrap_err(),
                FftError::Unsupported(_)
            ));
            assert!(matches!(
                PlanSpec::new(64)
                    .strategy(Strategy::LinzerFeig)
                    .dtype(dtype)
                    .build_any()
                    .unwrap_err(),
                FftError::UnsupportedStrategy { strategy: Strategy::LinzerFeig, .. }
            ));
        }
    }

    #[test]
    fn all_algorithms_agree_on_pow4_size() {
        let n = 64;
        let mut rng = Pcg32::seed(7);
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let reference = {
            let t = PlanSpec::new(n).stockham().build::<f64>().unwrap();
            let mut b = SplitBuf::from_f64(&re, &im);
            t.execute_alloc(&mut b);
            b.to_f64()
        };
        for alg in [Algorithm::Radix4, Algorithm::Dit, Algorithm::Bluestein] {
            let t = PlanSpec::new(n).algorithm(alg).build::<f64>().unwrap();
            let mut b = SplitBuf::from_f64(&re, &im);
            t.execute_alloc(&mut b);
            let (gr, gi) = b.to_f64();
            assert!(
                rel_l2(&gr, &gi, &reference.0, &reference.1) < 1e-11,
                "{alg:?}"
            );
        }
    }
}
