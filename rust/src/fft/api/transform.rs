//! The [`Transform`] trait — one execute shape for every transform
//! kind (Stockham radix-2/4, DIT, Bluestein, real-input), so the
//! serving plane, the signal pipelines and the benches all drive
//! `dyn Transform<T>` instead of five concrete plan types.
//!
//! Contract:
//!
//! * `len()` is the logical frame length; every execute entry point
//!   panics (like the concrete plans always have) when a frame's
//!   length differs.
//! * [`Transform::execute_frame`] is the one required compute method:
//!   transform a single planar frame in place, drawing working
//!   buffers from a pooled [`Scratch`] (allocation-free once warm).
//! * [`Transform::execute_many`] runs a whole strided
//!   [`FrameBatchMut`] view — the serving hot path; the default loops
//!   `execute_frame`, and batched backends (e.g. a PJRT artifact)
//!   override one method.
//! * [`Transform::execute_into`] is the out-of-place form: the source
//!   view is preserved, results land in the destination view.
//! * `execute` / `execute_batch` / `execute_alloc` are the legacy
//!   owned-[`SplitBuf`] adapters, kept so no caller breaks; they route
//!   through `execute_frame`, so results are bit-identical across all
//!   entry points.

use crate::precision::{Real, SplitBuf};

use super::super::bluestein::BluesteinPlan;
use super::super::dit::DitPlan;
use super::super::plan::Plan;
use super::super::radix4::Radix4Plan;
use super::super::real_fft::RealFftPlan;
use super::super::{Direction, Strategy};
use super::batch::{FrameBatch, FrameBatchMut, Scratch};

/// A planned, executable transform over working precision `T`.
pub trait Transform<T: Real>: Send + Sync + core::fmt::Debug {
    /// Logical frame length (number of complex samples per execute).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Butterfly strategy baked into the plan's tables.
    fn strategy(&self) -> Strategy;

    /// Transform direction.
    fn direction(&self) -> Direction;

    /// Transform one planar frame in place.  `re`/`im` must both have
    /// length [`Transform::len`]; working buffers come from `scratch`
    /// and are returned to it before this call completes.
    fn execute_frame(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>);

    /// Execute every frame of a strided batch view in place, reusing
    /// one pooled `scratch` across the whole batch — the serving hot
    /// path (zero heap allocation once `scratch` is warm).
    fn execute_many(&self, mut batch: FrameBatchMut<'_, T>, scratch: &mut Scratch<T>) {
        assert_eq!(batch.frame_len(), self.len(), "batch frame length != plan size");
        for f in 0..batch.frames() {
            let (re, im) = batch.frame_mut(f);
            self.execute_frame(re, im, scratch);
        }
    }

    /// Out-of-place batch execute: copy `src` into `dst` (strides may
    /// differ), then transform `dst` in place.  The source view is
    /// preserved — the re-run/retry and compare paths rely on that.
    fn execute_into(
        &self,
        src: FrameBatch<'_, T>,
        mut dst: FrameBatchMut<'_, T>,
        scratch: &mut Scratch<T>,
    ) {
        assert_eq!(src.frame_len(), self.len(), "batch frame length != plan size");
        dst.copy_from(&src);
        self.execute_many(dst, scratch);
    }

    /// Execute in place. `buf.len()` must equal [`Transform::len`].
    /// (Legacy owned-buffer adapter over [`Transform::execute_frame`];
    /// the caller's `scratch` buffer is pooled for the call and one
    /// buffer is handed back so repeated calls stay amortized.)
    fn execute(&self, buf: &mut SplitBuf<T>, scratch: &mut SplitBuf<T>) {
        assert_eq!(buf.len(), self.len(), "buffer length != plan size");
        let mut pool = Scratch::new();
        pool.put(core::mem::take(scratch));
        self.execute_frame(&mut buf.re, &mut buf.im, &mut pool);
        *scratch = pool.take(self.len());
    }

    /// Execute a whole batch of same-length frames, reusing `scratch`.
    /// (Legacy vec-of-bufs adapter; new code hands the coordinator an
    /// arena view via [`Transform::execute_many`].)
    fn execute_batch(&self, bufs: &mut [SplitBuf<T>], scratch: &mut SplitBuf<T>) {
        let mut pool = Scratch::new();
        pool.put(core::mem::take(scratch));
        for buf in bufs.iter_mut() {
            assert_eq!(buf.len(), self.len(), "buffer length != plan size");
            self.execute_frame(&mut buf.re, &mut buf.im, &mut pool);
        }
        *scratch = pool.take(self.len());
    }

    /// Convenience: allocate scratch internally (not for the hot path).
    fn execute_alloc(&self, buf: &mut SplitBuf<T>) {
        assert_eq!(buf.len(), self.len(), "buffer length != plan size");
        let mut pool = Scratch::new();
        self.execute_frame(&mut buf.re, &mut buf.im, &mut pool);
    }
}

impl<T: Real> Transform<T> for Plan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        self.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute_frame(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        let mut work = scratch.take(self.n);
        crate::fft::stockham::execute_in(self, re, im, &mut work.re, &mut work.im);
        scratch.put(work);
    }
}

impl<T: Real> Transform<T> for Radix4Plan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        self.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute_frame(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        let mut work = scratch.take(self.n);
        Radix4Plan::execute_in(self, re, im, &mut work.re, &mut work.im);
        scratch.put(work);
    }
}

impl<T: Real> Transform<T> for DitPlan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        self.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute_frame(&self, re: &mut [T], im: &mut [T], _scratch: &mut Scratch<T>) {
        // The DIT transform is fully in place (bit-reversal + stages).
        DitPlan::execute_in(self, re, im);
    }
}

impl<T: Real> Transform<T> for BluesteinPlan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        BluesteinPlan::strategy(self)
    }
    fn direction(&self) -> Direction {
        BluesteinPlan::direction(self)
    }
    fn execute_frame(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        BluesteinPlan::execute_in(self, re, im, scratch);
    }
}

/// Real-input transform behind the facade: full-spectrum semantics so
/// it composes with the complex transforms.
///
/// * Forward: the frame's `re` plane holds the length-n real signal
///   (`im` is ignored); after execute, the frame holds the full
///   complex spectrum — bins `0..=n/2` computed by the half-size
///   packing trick ([`RealFftPlan`]), bins `n/2+1..n` filled by
///   Hermitian symmetry.  The result matches a complex FFT of the
///   same real signal.
/// * Inverse: the frame holds a Hermitian spectrum (only bins
///   `0..=n/2` are read); after execute, `re` holds the real signal
///   and `im` is zero.
#[derive(Debug)]
pub struct RealTransform<T: Real> {
    plan: RealFftPlan<T>,
    direction: Direction,
}

impl<T: Real> RealTransform<T> {
    pub fn new(plan: RealFftPlan<T>, direction: Direction) -> Self {
        RealTransform { plan, direction }
    }

    /// The underlying half-size r2c/c2r plan.
    pub fn inner(&self) -> &RealFftPlan<T> {
        &self.plan
    }
}

impl<T: Real> Transform<T> for RealTransform<T> {
    fn len(&self) -> usize {
        self.plan.n
    }
    fn strategy(&self) -> Strategy {
        self.plan.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute_frame(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        match self.direction {
            Direction::Forward => self.plan.forward_full(re, im, scratch),
            Direction::Inverse => self.plan.inverse_full(re, im, scratch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::batch::FrameArena;
    use super::*;
    use crate::dft;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    fn boxed(n: usize) -> Box<dyn Transform<f64>> {
        Box::new(Plan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap())
    }

    #[test]
    fn trait_object_executes_like_concrete_plan() {
        let n = 64;
        let t = boxed(n);
        assert_eq!(t.len(), n);
        assert_eq!(t.strategy(), Strategy::DualSelect);
        assert_eq!(t.direction(), Direction::Forward);
        let mut rng = Pcg32::seed(1);
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mut buf = SplitBuf::from_f64(&re, &im);
        t.execute_alloc(&mut buf);
        let (wr, wi) = dft::naive_dft(&re, &im, false);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &wr, &wi) < 1e-12);
    }

    #[test]
    fn default_batch_loop_matches_single_executes() {
        let n = 32;
        let t = boxed(n);
        let mut rng = Pcg32::seed(2);
        let frames: Vec<(Vec<f64>, Vec<f64>)> = (0..5)
            .map(|_| {
                (
                    (0..n).map(|_| rng.gaussian()).collect(),
                    (0..n).map(|_| rng.gaussian()).collect(),
                )
            })
            .collect();
        let mut batch: Vec<SplitBuf<f64>> =
            frames.iter().map(|(r, i)| SplitBuf::from_f64(r, i)).collect();
        let mut scratch = SplitBuf::zeroed(n);
        t.execute_batch(&mut batch, &mut scratch);
        for ((r, i), got) in frames.iter().zip(&batch) {
            let mut single = SplitBuf::from_f64(r, i);
            t.execute_alloc(&mut single);
            assert_eq!(single, *got);
        }
    }

    #[test]
    fn execute_many_over_arena_matches_per_frame_execute() {
        let n = 64;
        let t = boxed(n);
        let mut rng = Pcg32::seed(9);
        let mut arena = FrameArena::<f64>::new(n);
        let mut singles = Vec::new();
        for _ in 0..4 {
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            arena.push_frame_f64(&re, &im);
            singles.push(SplitBuf::<f64>::from_f64(&re, &im));
        }
        let mut scratch = Scratch::new();
        t.execute_many(arena.view_mut(), &mut scratch);
        for (f, single) in singles.iter_mut().enumerate() {
            t.execute_alloc(single);
            assert_eq!(arena.frame_to_split(f), *single, "frame {f}");
        }
    }

    #[test]
    fn execute_into_preserves_source() {
        let n = 32;
        let t = boxed(n);
        let mut rng = Pcg32::seed(10);
        let mut src = FrameArena::<f64>::new(n);
        for _ in 0..3 {
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            src.push_frame_f64(&re, &im);
        }
        let pristine = src.clone();
        let mut dst = FrameArena::<f64>::new(n);
        for _ in 0..3 {
            dst.push_zeroed();
        }
        let mut scratch = Scratch::new();
        t.execute_into(src.view(), dst.view_mut(), &mut scratch);
        assert_eq!(src, pristine, "source mutated");
        for f in 0..3 {
            let mut single = pristine.frame_to_split(f);
            t.execute_alloc(&mut single);
            assert_eq!(dst.frame_to_split(f), single, "frame {f}");
        }
    }

    #[test]
    fn real_transform_matches_complex_fft_full_spectrum() {
        let n = 128;
        let mut rng = Pcg32::seed(3);
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let rt = RealTransform::new(
            RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap(),
            Direction::Forward,
        );
        let mut buf = SplitBuf::from_f64(&x, &vec![0.0; n]);
        let mut scratch = SplitBuf::zeroed(n);
        rt.execute(&mut buf, &mut scratch);
        let (wr, wi) = dft::naive_dft(&x, &vec![0.0; n], false);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &wr, &wi) < 1e-12);
    }

    #[test]
    fn real_roundtrip_is_identity() {
        let n = 256;
        let mut rng = Pcg32::seed(4);
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let fwd = RealTransform::new(
            RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap(),
            Direction::Forward,
        );
        let inv = RealTransform::new(
            RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap(),
            Direction::Inverse,
        );
        let mut buf = SplitBuf::from_f64(&x, &vec![0.0; n]);
        let mut scratch = SplitBuf::zeroed(n);
        fwd.execute(&mut buf, &mut scratch);
        inv.execute(&mut buf, &mut scratch);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &x, &vec![0.0; n]) < 1e-12);
    }

    #[test]
    fn scratch_stops_allocating_after_warmup() {
        // Every plan kind's execute_frame must be served entirely from
        // the pool on the second and later frames.
        let kinds: Vec<Box<dyn Transform<f64>>> = vec![
            Box::new(Plan::<f64>::new(64, Strategy::DualSelect, Direction::Forward).unwrap()),
            Box::new(
                Radix4Plan::<f64>::new(64, Strategy::DualSelect, Direction::Forward).unwrap(),
            ),
            Box::new(DitPlan::<f64>::new(64, Strategy::DualSelect, Direction::Forward).unwrap()),
            Box::new(
                BluesteinPlan::<f64>::new(60, Strategy::DualSelect, Direction::Forward).unwrap(),
            ),
            Box::new(RealTransform::new(
                RealFftPlan::<f64>::new(64, Strategy::DualSelect).unwrap(),
                Direction::Forward,
            )),
        ];
        for t in &kinds {
            let n = t.len();
            let mut scratch = Scratch::new();
            let mut arena = FrameArena::<f64>::new(n);
            for _ in 0..8 {
                arena.push_zeroed();
            }
            t.execute_many(arena.view_mut(), &mut scratch);
            let warm = scratch.misses();
            t.execute_many(arena.view_mut(), &mut scratch);
            t.execute_many(arena.view_mut(), &mut scratch);
            assert_eq!(scratch.misses(), warm, "{t:?} allocated after warmup");
        }
    }
}
