//! The [`Transform`] trait — one execute shape for every transform
//! kind (Stockham radix-2/4, DIT, Bluestein, real-input), so the
//! serving plane, the signal pipelines and the benches all drive
//! `dyn Transform<T>` instead of five concrete plan types.
//!
//! Contract:
//!
//! * `len()` is the logical frame length; `execute` panics (like every
//!   plan's concrete `execute` always has) if `buf.len() != len()`.
//! * `execute` transforms `buf` in place; `scratch` is working space
//!   that is resized on demand and carries no state between calls.
//! * `execute_batch` has a default serial loop; the coordinator's
//!   worker pool calls it so backends that can do better (e.g. a
//!   batched PJRT artifact) override one method instead of the server
//!   hand-rolling per-request dispatch.

use crate::precision::{Real, SplitBuf};

use super::super::bluestein::BluesteinPlan;
use super::super::dit::DitPlan;
use super::super::plan::Plan;
use super::super::radix4::Radix4Plan;
use super::super::real_fft::RealFftPlan;
use super::super::{Direction, Strategy};

/// A planned, executable transform over working precision `T`.
pub trait Transform<T: Real>: Send + Sync + core::fmt::Debug {
    /// Logical frame length (number of complex samples per execute).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Butterfly strategy baked into the plan's tables.
    fn strategy(&self) -> Strategy;

    /// Transform direction.
    fn direction(&self) -> Direction;

    /// Execute in place. `buf.len()` must equal [`Transform::len`];
    /// `scratch` is resized when needed.
    fn execute(&self, buf: &mut SplitBuf<T>, scratch: &mut SplitBuf<T>);

    /// Execute a whole batch of same-length frames, reusing `scratch`.
    fn execute_batch(&self, bufs: &mut [SplitBuf<T>], scratch: &mut SplitBuf<T>) {
        for buf in bufs.iter_mut() {
            self.execute(buf, scratch);
        }
    }

    /// Convenience: allocate scratch internally (not for the hot path).
    fn execute_alloc(&self, buf: &mut SplitBuf<T>) {
        let mut scratch = SplitBuf::zeroed(self.len());
        self.execute(buf, &mut scratch);
    }
}

impl<T: Real> Transform<T> for Plan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        self.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute(&self, buf: &mut SplitBuf<T>, scratch: &mut SplitBuf<T>) {
        crate::fft::stockham::execute(self, buf, scratch);
    }
}

impl<T: Real> Transform<T> for Radix4Plan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        self.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute(&self, buf: &mut SplitBuf<T>, scratch: &mut SplitBuf<T>) {
        Radix4Plan::execute(self, buf, scratch);
    }
}

impl<T: Real> Transform<T> for DitPlan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        self.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute(&self, buf: &mut SplitBuf<T>, _scratch: &mut SplitBuf<T>) {
        // The DIT transform is fully in place (bit-reversal + stages).
        DitPlan::execute(self, buf);
    }
}

impl<T: Real> Transform<T> for BluesteinPlan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        BluesteinPlan::strategy(self)
    }
    fn direction(&self) -> Direction {
        BluesteinPlan::direction(self)
    }
    fn execute(&self, buf: &mut SplitBuf<T>, _scratch: &mut SplitBuf<T>) {
        *buf = self.transform(buf);
    }
}

/// Real-input transform behind the facade: full-spectrum semantics so
/// it composes with the complex transforms.
///
/// * Forward: `buf.re` holds the length-n real signal (`buf.im` is
///   ignored); after execute, `buf` holds the full complex spectrum —
///   bins `0..=n/2` computed by the half-size packing trick
///   ([`RealFftPlan`]), bins `n/2+1..n` filled by Hermitian symmetry.
///   The result matches a complex FFT of the same real signal.
/// * Inverse: `buf` holds a Hermitian spectrum (only bins `0..=n/2`
///   are read); after execute, `buf.re` holds the real signal and
///   `buf.im` is zero.
#[derive(Debug)]
pub struct RealTransform<T: Real> {
    plan: RealFftPlan<T>,
    direction: Direction,
}

impl<T: Real> RealTransform<T> {
    pub fn new(plan: RealFftPlan<T>, direction: Direction) -> Self {
        RealTransform { plan, direction }
    }

    /// The underlying half-size r2c/c2r plan.
    pub fn inner(&self) -> &RealFftPlan<T> {
        &self.plan
    }
}

impl<T: Real> Transform<T> for RealTransform<T> {
    fn len(&self) -> usize {
        self.plan.n
    }
    fn strategy(&self) -> Strategy {
        self.plan.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute(&self, buf: &mut SplitBuf<T>, _scratch: &mut SplitBuf<T>) {
        let n = self.plan.n;
        assert_eq!(buf.len(), n, "buffer length != plan size");
        let half = n / 2;
        match self.direction {
            Direction::Forward => {
                let spec = self.plan.execute(&buf.re);
                for k in 0..=half {
                    buf.re[k] = spec.re[k];
                    buf.im[k] = spec.im[k];
                }
                for k in half + 1..n {
                    buf.re[k] = spec.re[n - k];
                    buf.im[k] = -spec.im[n - k];
                }
            }
            Direction::Inverse => {
                let mut spec = SplitBuf::<T>::zeroed(half + 1);
                spec.re.copy_from_slice(&buf.re[..=half]);
                spec.im.copy_from_slice(&buf.im[..=half]);
                let x = self
                    .plan
                    .execute_inverse(&spec)
                    .expect("spec length is half+1 by construction");
                buf.re.copy_from_slice(&x);
                for v in buf.im.iter_mut() {
                    *v = T::zero();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    fn boxed(n: usize) -> Box<dyn Transform<f64>> {
        Box::new(Plan::<f64>::new(n, Strategy::DualSelect, Direction::Forward).unwrap())
    }

    #[test]
    fn trait_object_executes_like_concrete_plan() {
        let n = 64;
        let t = boxed(n);
        assert_eq!(t.len(), n);
        assert_eq!(t.strategy(), Strategy::DualSelect);
        assert_eq!(t.direction(), Direction::Forward);
        let mut rng = Pcg32::seed(1);
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mut buf = SplitBuf::from_f64(&re, &im);
        t.execute_alloc(&mut buf);
        let (wr, wi) = dft::naive_dft(&re, &im, false);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &wr, &wi) < 1e-12);
    }

    #[test]
    fn default_batch_loop_matches_single_executes() {
        let n = 32;
        let t = boxed(n);
        let mut rng = Pcg32::seed(2);
        let frames: Vec<(Vec<f64>, Vec<f64>)> = (0..5)
            .map(|_| {
                (
                    (0..n).map(|_| rng.gaussian()).collect(),
                    (0..n).map(|_| rng.gaussian()).collect(),
                )
            })
            .collect();
        let mut batch: Vec<SplitBuf<f64>> =
            frames.iter().map(|(r, i)| SplitBuf::from_f64(r, i)).collect();
        let mut scratch = SplitBuf::zeroed(n);
        t.execute_batch(&mut batch, &mut scratch);
        for ((r, i), got) in frames.iter().zip(&batch) {
            let mut single = SplitBuf::from_f64(r, i);
            t.execute_alloc(&mut single);
            assert_eq!(single, *got);
        }
    }

    #[test]
    fn real_transform_matches_complex_fft_full_spectrum() {
        let n = 128;
        let mut rng = Pcg32::seed(3);
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let rt = RealTransform::new(
            RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap(),
            Direction::Forward,
        );
        let mut buf = SplitBuf::from_f64(&x, &vec![0.0; n]);
        let mut scratch = SplitBuf::zeroed(n);
        rt.execute(&mut buf, &mut scratch);
        let (wr, wi) = dft::naive_dft(&x, &vec![0.0; n], false);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &wr, &wi) < 1e-12);
    }

    #[test]
    fn real_roundtrip_is_identity() {
        let n = 256;
        let mut rng = Pcg32::seed(4);
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let fwd = RealTransform::new(
            RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap(),
            Direction::Forward,
        );
        let inv = RealTransform::new(
            RealFftPlan::<f64>::new(n, Strategy::DualSelect).unwrap(),
            Direction::Inverse,
        );
        let mut buf = SplitBuf::from_f64(&x, &vec![0.0; n]);
        let mut scratch = SplitBuf::zeroed(n);
        fwd.execute(&mut buf, &mut scratch);
        inv.execute(&mut buf, &mut scratch);
        let (gr, gi) = buf.to_f64();
        assert!(rel_l2(&gr, &gi, &x, &vec![0.0; n]) < 1e-12);
    }
}
