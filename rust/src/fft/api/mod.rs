//! The public FFT facade: typed errors, the [`Transform`] trait, the
//! [`PlanSpec`] builder and the generalized [`Planner`].
//!
//! The paper's point is that dual-select is a drop-in table swap; this
//! module makes "drop-in" true at the API level too — one way to
//! describe any transform, one way to execute it, one error type:
//!
//! ```text
//!   PlanSpec::new(n)                      describe
//!       .strategy(Strategy::DualSelect)
//!       .direction(Direction::Inverse)
//!       .radix4()              // or .dit() / .bluestein() / .real_input()
//!       .build::<f32>()?                  -> Box<dyn Transform<f32>>
//!
//!   planner.get(spec)?                    same, cached -> Arc<dyn Transform<T>>
//!   transform.execute(&mut buf, &mut scratch)
//!   transform.execute_batch(&mut frames, &mut scratch)
//! ```
//!
//! Concrete plan types ([`super::Plan`], [`super::radix4::Radix4Plan`],
//! [`super::dit::DitPlan`], [`super::bluestein::BluesteinPlan`],
//! [`super::real_fft::RealFftPlan`]) remain public for code that wants
//! monomorphized access; they all implement [`Transform`].
//! See `DESIGN.md` for the facade diagram and migration notes.

pub mod error;
pub mod planner;
pub mod spec;
pub mod transform;

pub use error::{FftError, FftResult};
pub use planner::Planner;
pub use spec::{Algorithm, PlanSpec};
pub use transform::{RealTransform, Transform};
