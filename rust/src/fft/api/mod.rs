//! The public FFT facade: typed errors, the [`Transform`] trait, the
//! [`PlanSpec`] builder, the generalized [`Planner`] and the zero-copy
//! buffer layer ([`FrameArena`] / [`FrameBatch`] / [`FrameBatchMut`] /
//! [`Scratch`]).
//!
//! The paper's point is that dual-select is a drop-in table swap; this
//! module makes "drop-in" true at the API level too — one way to
//! describe any transform, one way to execute it, one error type:
//!
//! ```text
//!   PlanSpec::new(n)                      describe
//!       .strategy(Strategy::DualSelect)
//!       .direction(Direction::Inverse)
//!       .radix4()              // or .dit() / .bluestein() / .real_input()
//!       .build::<f32>()?                  -> Box<dyn Transform<f32>>
//!
//!   planner.get(spec)?                    same, cached -> Arc<dyn Transform<T>>
//!
//!   // Hot path: frames live in a planar arena, workers own a pooled
//!   // scratch — no per-frame buffers, no allocation after warmup.
//!   arena.push_frame_f64(&re, &im);       ingest (one rounding pass)
//!   transform.execute_many(arena.view_mut(), &mut scratch);
//!   transform.execute_into(src.view(), dst.view_mut(), &mut scratch);
//!
//!   // Legacy adapters (owned buffers) still work, bit-identically:
//!   transform.execute(&mut buf, &mut scratch_buf)
//!   transform.execute_batch(&mut frames, &mut scratch_buf)
//!
//!   // Pick the working precision at run time (the serving plane's
//!   // shape — see the [`dtype`] module):
//!   PlanSpec::new(n).dtype(DType::F16).build_any()?   -> AnyTransform
//!   any.execute_many_any(&mut any_arena, &mut any_scratch)?
//! ```
//!
//! Concrete plan types ([`super::Plan`], [`super::radix4::Radix4Plan`],
//! [`super::dit::DitPlan`], [`super::bluestein::BluesteinPlan`],
//! [`super::real_fft::RealFftPlan`]) remain public for code that wants
//! monomorphized access; they all implement [`Transform`].
//! See `DESIGN.md` for the facade diagram, the buffer-layer layout
//! contract and migration notes.

pub mod batch;
pub mod dtype;
pub mod error;
pub mod planner;
pub mod spec;
pub mod transform;

pub use batch::{ArenaPool, FrameArena, FrameBatch, FrameBatchMut, Scratch};
pub use dtype::{AnyArena, AnyArenaPool, AnyPlanner, AnyScratch, AnyTransform, DType};
pub use error::{FftError, FftResult};
pub use planner::Planner;
pub use spec::{Algorithm, PlanSpec};
pub use transform::{RealTransform, Transform};
