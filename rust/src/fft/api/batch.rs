//! The buffer layer of the execution API: contiguous planar batch
//! storage ([`FrameArena`]), borrowed strided views ([`FrameBatch`] /
//! [`FrameBatchMut`]), a pooled scratch allocator ([`Scratch`]) and an
//! arena recycler ([`ArenaPool`]).
//!
//! The paper's butterflies cost nothing extra at run time; at serving
//! scale the bottleneck is the memory traffic *around* them.  This
//! module fixes the layout so that traffic is one pass:
//!
//! ```text
//!   FrameArena<T>              owns planar storage, frame-major:
//!     re: [f0 f0 .. | f1 f1 .. | ..]   frame i at [i*frame_len ..)
//!     im: [f0 f0 .. | f1 f1 .. | ..]
//!        │
//!        ├── view()      -> FrameBatch<'_, T>     (shared, strided)
//!        └── view_mut()  -> FrameBatchMut<'_, T>  (exclusive, strided)
//!
//!   Scratch<T>                 per-worker pool of SplitBuf working
//!                              buffers; take()/put() never allocate
//!                              once the pool is warm
//!
//!   ArenaPool<T>               recycles arenas whose response handles
//!                              have all been dropped (Arc count == 1)
//! ```
//!
//! Layout contract (every kernel relies on it):
//!
//! * re/im are separate planes (split format — same as [`SplitBuf`]).
//! * Frame `i` occupies `[i*stride, i*stride + frame_len)` in both
//!   planes; `stride >= frame_len` (the gap, if any, is never touched).
//! * Views never own memory; an arena view has `stride == frame_len`.

use std::sync::{Arc, Mutex, PoisonError};

use crate::precision::{Real, SplitBuf};

/// Owned planar frame storage: `frames` frames of `frame_len` complex
/// samples, frame-major, contiguous (`stride == frame_len`).
///
/// Intake paths append with [`FrameArena::push_frame_f64`] (rounds f64
/// payloads into working precision in a single pass) or
/// [`FrameArena::push_interleaved_f64`] (splits `[re, im, re, im, ..]`
/// sources in a single pass).  [`FrameArena::reset`] keeps the
/// allocation, so a recycled arena serves the next batch without
/// touching the allocator.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FrameArena<T: Real> {
    re: Vec<T>,
    im: Vec<T>,
    frame_len: usize,
    frames: usize,
}

impl<T: Real> FrameArena<T> {
    /// An empty arena for frames of `frame_len` complex samples.
    pub fn new(frame_len: usize) -> Self {
        FrameArena { re: Vec::new(), im: Vec::new(), frame_len, frames: 0 }
    }

    /// Pre-size for `frames` frames (one allocation up front).
    pub fn with_capacity(frame_len: usize, frames: usize) -> Self {
        let mut a = FrameArena::new(frame_len);
        a.reserve_frames(frames);
        a
    }

    /// Samples per frame.
    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    /// Number of frames currently stored.
    pub fn frames(&self) -> usize {
        self.frames
    }

    pub fn is_empty(&self) -> bool {
        self.frames == 0
    }

    /// Frames that fit without reallocating.
    pub fn capacity_frames(&self) -> usize {
        if self.frame_len == 0 {
            return 0;
        }
        self.re.capacity().min(self.im.capacity()) / self.frame_len
    }

    /// Ensure room for `frames` frames total.
    pub fn reserve_frames(&mut self, frames: usize) {
        let want = frames * self.frame_len;
        self.re.reserve(want.saturating_sub(self.re.len()));
        self.im.reserve(want.saturating_sub(self.im.len()));
    }

    /// Drop all frames, keep the allocation.
    pub fn clear(&mut self) {
        self.re.clear();
        self.im.clear();
        self.frames = 0;
    }

    /// Re-purpose the arena (possibly for a new frame length), keeping
    /// the allocation — the recycle path of [`ArenaPool`].
    pub fn reset(&mut self, frame_len: usize) {
        self.clear();
        self.frame_len = frame_len;
    }

    /// Append a zeroed frame; returns its index.
    pub fn push_zeroed(&mut self) -> usize {
        let new_len = self.re.len() + self.frame_len;
        self.re.resize(new_len, T::zero());
        self.im.resize(new_len, T::zero());
        self.frames += 1;
        self.frames - 1
    }

    /// Append a frame from split f64 payloads, rounding into working
    /// precision in one pass; returns the frame index.
    pub fn push_frame_f64(&mut self, re: &[f64], im: &[f64]) -> usize {
        assert_eq!(re.len(), self.frame_len, "frame length != arena frame_len");
        assert_eq!(im.len(), self.frame_len, "frame length != arena frame_len");
        self.re.extend(re.iter().map(|&x| T::from_f64(x)));
        self.im.extend(im.iter().map(|&x| T::from_f64(x)));
        self.frames += 1;
        self.frames - 1
    }

    /// Append a frame from an interleaved `[re, im, re, im, ..]` f64
    /// source (length `2 * frame_len`) in a single pass.
    pub fn push_interleaved_f64(&mut self, zs: &[f64]) -> usize {
        assert_eq!(zs.len(), 2 * self.frame_len, "interleaved length != 2*frame_len");
        self.re.reserve(self.frame_len);
        self.im.reserve(self.frame_len);
        for pair in zs.chunks_exact(2) {
            self.re.push(T::from_f64(pair[0]));
            self.im.push(T::from_f64(pair[1]));
        }
        self.frames += 1;
        self.frames - 1
    }

    /// Append a frame already in working precision.
    pub fn push_split(&mut self, buf: &SplitBuf<T>) -> usize {
        assert_eq!(buf.len(), self.frame_len, "frame length != arena frame_len");
        self.re.extend_from_slice(&buf.re);
        self.im.extend_from_slice(&buf.im);
        self.frames += 1;
        self.frames - 1
    }

    /// Borrow frame `i` as planar slices.
    pub fn frame(&self, i: usize) -> (&[T], &[T]) {
        assert!(i < self.frames, "frame index {i} out of range ({})", self.frames);
        let a = i * self.frame_len;
        let b = a + self.frame_len;
        (&self.re[a..b], &self.im[a..b])
    }

    /// Mutably borrow frame `i` as planar slices.
    pub fn frame_mut(&mut self, i: usize) -> (&mut [T], &mut [T]) {
        assert!(i < self.frames, "frame index {i} out of range ({})", self.frames);
        let a = i * self.frame_len;
        let b = a + self.frame_len;
        (&mut self.re[a..b], &mut self.im[a..b])
    }

    /// Shared view over all frames.
    pub fn view(&self) -> FrameBatch<'_, T> {
        FrameBatch {
            re: &self.re[..],
            im: &self.im[..],
            frames: self.frames,
            frame_len: self.frame_len,
            stride: self.frame_len,
        }
    }

    /// Exclusive view over all frames — what
    /// [`super::Transform::execute_many`] consumes.
    pub fn view_mut(&mut self) -> FrameBatchMut<'_, T> {
        FrameBatchMut {
            re: &mut self.re[..],
            im: &mut self.im[..],
            frames: self.frames,
            frame_len: self.frame_len,
            stride: self.frame_len,
        }
    }

    /// Copy frame `i` out into an owned [`SplitBuf`] (test/compat
    /// convenience — the hot path reads slices via [`FrameArena::frame`]).
    pub fn frame_to_split(&self, i: usize) -> SplitBuf<T> {
        let (re, im) = self.frame(i);
        SplitBuf { re: re.to_vec(), im: im.to_vec() }
    }
}

fn check_batch_geometry<T>(
    re: &[T],
    im: &[T],
    frames: usize,
    frame_len: usize,
    stride: usize,
) {
    assert_eq!(re.len(), im.len(), "re/im planes differ in length");
    assert!(stride >= frame_len, "stride {stride} < frame_len {frame_len}");
    if frames > 0 {
        let span = (frames - 1) * stride + frame_len;
        assert!(
            span <= re.len(),
            "batch needs {span} samples per plane, planes hold {}",
            re.len()
        );
    }
}

/// Borrowed, read-only, strided view of a frame batch.
///
/// Frame `i` lives at `[i*stride, i*stride + frame_len)` in both
/// planes.  `stride > frame_len` lets a view address frames embedded
/// in a larger layout (row-padded matrices, interleaved pools) without
/// copying.
#[derive(Clone, Copy, Debug)]
pub struct FrameBatch<'a, T: Real> {
    re: &'a [T],
    im: &'a [T],
    frames: usize,
    frame_len: usize,
    stride: usize,
}

impl<'a, T: Real> FrameBatch<'a, T> {
    /// Contiguous view: `stride == frame_len`, frame count inferred.
    pub fn new(re: &'a [T], im: &'a [T], frame_len: usize) -> Self {
        assert!(frame_len > 0, "frame_len must be positive");
        assert_eq!(re.len() % frame_len, 0, "plane length not a multiple of frame_len");
        let frames = re.len() / frame_len;
        Self::with_stride(re, im, frames, frame_len, frame_len)
    }

    /// Explicit-stride view.
    pub fn with_stride(
        re: &'a [T],
        im: &'a [T],
        frames: usize,
        frame_len: usize,
        stride: usize,
    ) -> Self {
        check_batch_geometry(re, im, frames, frame_len, stride);
        FrameBatch { re, im, frames, frame_len, stride }
    }

    pub fn frames(&self) -> usize {
        self.frames
    }

    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    pub fn is_empty(&self) -> bool {
        self.frames == 0
    }

    /// Borrow frame `i` as planar slices.
    pub fn frame(&self, i: usize) -> (&[T], &[T]) {
        assert!(i < self.frames, "frame index {i} out of range ({})", self.frames);
        let a = i * self.stride;
        let b = a + self.frame_len;
        (&self.re[a..b], &self.im[a..b])
    }
}

/// Borrowed, exclusive, strided view of a frame batch — the argument
/// of [`super::Transform::execute_many`].
#[derive(Debug)]
pub struct FrameBatchMut<'a, T: Real> {
    re: &'a mut [T],
    im: &'a mut [T],
    frames: usize,
    frame_len: usize,
    stride: usize,
}

impl<'a, T: Real> FrameBatchMut<'a, T> {
    /// Contiguous view: `stride == frame_len`, frame count inferred.
    pub fn new(re: &'a mut [T], im: &'a mut [T], frame_len: usize) -> Self {
        assert!(frame_len > 0, "frame_len must be positive");
        assert_eq!(re.len() % frame_len, 0, "plane length not a multiple of frame_len");
        let frames = re.len() / frame_len;
        Self::with_stride(re, im, frames, frame_len, frame_len)
    }

    /// Explicit-stride view.
    pub fn with_stride(
        re: &'a mut [T],
        im: &'a mut [T],
        frames: usize,
        frame_len: usize,
        stride: usize,
    ) -> Self {
        check_batch_geometry(re, im, frames, frame_len, stride);
        FrameBatchMut { re, im, frames, frame_len, stride }
    }

    pub fn frames(&self) -> usize {
        self.frames
    }

    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    pub fn is_empty(&self) -> bool {
        self.frames == 0
    }

    /// Borrow frame `i` read-only.
    pub fn frame(&self, i: usize) -> (&[T], &[T]) {
        assert!(i < self.frames, "frame index {i} out of range ({})", self.frames);
        let a = i * self.stride;
        let b = a + self.frame_len;
        (&self.re[a..b], &self.im[a..b])
    }

    /// Borrow frame `i` mutably as planar slices.
    pub fn frame_mut(&mut self, i: usize) -> (&mut [T], &mut [T]) {
        assert!(i < self.frames, "frame index {i} out of range ({})", self.frames);
        let a = i * self.stride;
        let b = a + self.frame_len;
        (&mut self.re[a..b], &mut self.im[a..b])
    }

    /// Reborrow with a shorter lifetime (lets a by-value view be used
    /// more than once).
    pub fn reborrow(&mut self) -> FrameBatchMut<'_, T> {
        FrameBatchMut {
            re: &mut self.re[..],
            im: &mut self.im[..],
            frames: self.frames,
            frame_len: self.frame_len,
            stride: self.stride,
        }
    }

    /// Downgrade to a shared view.
    pub fn as_shared(&self) -> FrameBatch<'_, T> {
        FrameBatch {
            re: &self.re[..],
            im: &self.im[..],
            frames: self.frames,
            frame_len: self.frame_len,
            stride: self.stride,
        }
    }

    /// Copy every frame of `src` into this view (frame counts and
    /// lengths must match; strides may differ).
    pub fn copy_from(&mut self, src: &FrameBatch<'_, T>) {
        assert_eq!(src.frames(), self.frames, "frame count mismatch");
        assert_eq!(src.frame_len(), self.frame_len, "frame length mismatch");
        for i in 0..self.frames {
            let (sre, sim) = src.frame(i);
            let (dre, dim) = self.frame_mut(i);
            dre.copy_from_slice(sre);
            dim.copy_from_slice(sim);
        }
    }
}

/// A per-worker pool of working buffers.  Kernels `take` the scratch
/// they need and `put` it back; after the first batch (warmup) every
/// `take` is served from the pool without touching the allocator.
///
/// `take` returns a buffer of exactly the requested length whose
/// *contents are unspecified* — kernels that read before writing must
/// use [`Scratch::take_zeroed`].
#[derive(Debug, Default)]
pub struct Scratch<T: Real> {
    pool: Vec<SplitBuf<T>>,
    takes: u64,
    misses: u64,
}

impl<T: Real> Scratch<T> {
    pub fn new() -> Self {
        Scratch { pool: Vec::new(), takes: 0, misses: 0 }
    }

    /// Total `take`/`take_zeroed` calls served.
    pub fn takes(&self) -> u64 {
        self.takes
    }

    /// `take` calls that had to allocate (no pooled buffer large
    /// enough).  Flat after warmup — asserted by the allocation
    /// regression test.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }

    /// Take a buffer of length `len` with unspecified contents.
    /// Served from the pool (best capacity fit) when possible.
    pub fn take(&mut self, len: usize) -> SplitBuf<T> {
        self.takes += 1;
        let cap_of = |b: &SplitBuf<T>| b.re.capacity().min(b.im.capacity());
        let mut best: Option<usize> = None;
        for (i, b) in self.pool.iter().enumerate() {
            let cap = cap_of(b);
            if cap >= len && best.map_or(true, |j| cap < cap_of(&self.pool[j])) {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let mut b = self.pool.swap_remove(i);
                // Within capacity: resize never reallocates here.
                b.re.resize(len, T::zero());
                b.im.resize(len, T::zero());
                b
            }
            None => {
                self.misses += 1;
                SplitBuf::zeroed(len)
            }
        }
    }

    /// Take a buffer of length `len` with every sample zeroed.
    pub fn take_zeroed(&mut self, len: usize) -> SplitBuf<T> {
        let mut b = self.take(len);
        b.re.fill(T::zero());
        b.im.fill(T::zero());
        b
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, buf: SplitBuf<T>) {
        self.pool.push(buf);
    }
}

/// Shared recycler for [`FrameArena`]s that travel through the serving
/// plane inside `Arc`s (batch → responses).  Once every response
/// handle is dropped the arena's refcount falls to 1 and the next
/// [`ArenaPool::take`] reclaims its allocation instead of allocating.
#[derive(Debug, Default)]
pub struct ArenaPool<T: Real> {
    parked: Mutex<Vec<Arc<FrameArena<T>>>>,
}

/// Cap on parked arenas; beyond this, recycled arenas are dropped
/// (bounds memory if clients hold responses for a long time).
const ARENA_POOL_CAP: usize = 64;

impl<T: Real> ArenaPool<T> {
    pub fn new() -> Self {
        ArenaPool { parked: Mutex::new(Vec::new()) }
    }

    /// Take an arena configured for `frame_len`, reusing a parked one
    /// whose clients have all dropped their handles.
    pub fn take(&self, frame_len: usize) -> FrameArena<T> {
        let mut parked = self.parked.lock().unwrap_or_else(PoisonError::into_inner);
        let mut i = 0;
        while i < parked.len() {
            if Arc::strong_count(&parked[i]) == 1 {
                let arc = parked.swap_remove(i);
                // The pool lock is held and the parked Vec owned the
                // only handle, so no new clone can appear between the
                // strong_count check and the unwrap.
                let mut arena = Arc::try_unwrap(arc).unwrap_or_else(|_| {
                    unreachable!("sole Arc handle observed under the pool lock")
                });
                arena.reset(frame_len);
                return arena;
            }
            i += 1;
        }
        FrameArena::new(frame_len)
    }

    /// Park a shared arena for future reclamation.
    pub fn recycle(&self, arena: Arc<FrameArena<T>>) {
        let mut parked = self.parked.lock().unwrap_or_else(PoisonError::into_inner);
        if parked.len() < ARENA_POOL_CAP {
            parked.push(arena);
        }
    }

    /// Arenas currently parked (in any refcount state).
    pub fn parked(&self) -> usize {
        self.parked
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_push_and_view_layout() {
        let mut a = FrameArena::<f32>::new(4);
        assert!(a.is_empty());
        a.push_frame_f64(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        a.push_interleaved_f64(&[9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0, 16.0]);
        assert_eq!(a.frames(), 2);
        let (re0, im0) = a.frame(0);
        assert_eq!(re0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(im0, &[5.0, 6.0, 7.0, 8.0]);
        let (re1, im1) = a.frame(1);
        assert_eq!(re1, &[9.0, 11.0, 13.0, 15.0]);
        assert_eq!(im1, &[10.0, 12.0, 14.0, 16.0]);
        let v = a.view();
        assert_eq!(v.frames(), 2);
        assert_eq!(v.stride(), 4);
        assert_eq!(v.frame(1).0, re1);
    }

    #[test]
    fn arena_reset_keeps_allocation() {
        let mut a = FrameArena::<f32>::with_capacity(8, 4);
        for _ in 0..4 {
            a.push_zeroed();
        }
        let cap = a.capacity_frames();
        assert!(cap >= 4);
        a.reset(8);
        assert_eq!(a.frames(), 0);
        assert_eq!(a.capacity_frames(), cap);
    }

    #[test]
    fn strided_view_addresses_padded_rows() {
        // 3 frames of 4 samples, stride 6 (2 samples of padding).
        let mut re = vec![0.0f64; 2 * 6 + 4];
        let mut im = vec![0.0f64; 2 * 6 + 4];
        for f in 0..3 {
            for j in 0..4 {
                re[f * 6 + j] = (10 * f + j) as f64;
                im[f * 6 + j] = -((10 * f + j) as f64);
            }
        }
        let v = FrameBatch::with_stride(&re, &im, 3, 4, 6);
        assert_eq!(v.frame(2).0, &[20.0, 21.0, 22.0, 23.0]);
        let mut vm = FrameBatchMut::with_stride(&mut re, &mut im, 3, 4, 6);
        vm.frame_mut(1).0[0] = 99.0;
        assert_eq!(re[6], 99.0);
        // Padding untouched.
        assert_eq!(re[4], 0.0);
        assert_eq!(re[5], 0.0);
    }

    #[test]
    #[should_panic(expected = "batch needs")]
    fn view_rejects_short_planes() {
        let re = vec![0.0f32; 7];
        let im = vec![0.0f32; 7];
        let _ = FrameBatch::with_stride(&re, &im, 2, 4, 4);
    }

    #[test]
    fn copy_from_between_strides() {
        let mut src_a = FrameArena::<f32>::new(3);
        src_a.push_frame_f64(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        src_a.push_frame_f64(&[7.0, 8.0, 9.0], &[1.5, 2.5, 3.5]);
        let mut dre = vec![0.0f32; 2 * 5];
        let mut dim = vec![0.0f32; 2 * 5];
        let mut dst = FrameBatchMut::with_stride(&mut dre, &mut dim, 2, 3, 5);
        dst.copy_from(&src_a.view());
        assert_eq!(dst.frame(1).0, &[7.0, 8.0, 9.0]);
        assert_eq!(dre[5..8], [7.0, 8.0, 9.0]);
    }

    #[test]
    fn scratch_pool_amortizes() {
        let mut s = Scratch::<f32>::new();
        let b1 = s.take(128);
        assert_eq!(b1.len(), 128);
        assert_eq!(s.misses(), 1);
        s.put(b1);
        // Smaller and equal requests reuse the pooled buffer.
        let b2 = s.take(64);
        assert_eq!(b2.len(), 64);
        assert_eq!(s.misses(), 1);
        s.put(b2);
        let b3 = s.take_zeroed(128);
        assert!(b3.re.iter().all(|&x| x == 0.0));
        assert_eq!(s.misses(), 1);
        s.put(b3);
        // A larger request is a (counted) miss.
        let b4 = s.take(256);
        assert_eq!(s.misses(), 2);
        s.put(b4);
        assert_eq!(s.pooled(), 2);
        assert_eq!(s.takes(), 4);
    }

    #[test]
    fn scratch_best_fit_prefers_smallest_sufficient() {
        let mut s = Scratch::<f32>::new();
        let small = SplitBuf::zeroed(16);
        let big = SplitBuf::zeroed(1024);
        s.put(big);
        s.put(small);
        let got = s.take(10);
        assert!(got.re.capacity() < 1024, "picked the oversized buffer");
        assert_eq!(s.misses(), 0);
    }

    #[test]
    fn arena_pool_recycles_when_handles_drop() {
        let pool = ArenaPool::<f32>::new();
        let mut a = pool.take(8);
        a.push_zeroed();
        a.reserve_frames(16);
        let cap = a.capacity_frames();
        let shared = Arc::new(a);
        let client = shared.clone();
        pool.recycle(shared);
        // Client still holds a handle: take() must not steal it.
        let fresh = pool.take(8);
        assert_eq!(fresh.capacity_frames(), 0);
        drop(client);
        // Now the parked arena is reclaimable, allocation intact.
        let reused = pool.take(8);
        assert_eq!(reused.frames(), 0);
        assert_eq!(reused.capacity_frames(), cap);
        assert_eq!(pool.parked(), 0);
    }
}
