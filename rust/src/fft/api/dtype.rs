//! The dtype layer of the execution API: a runtime description of the
//! working precision ([`DType`]) and the dtype-erased execution types
//! ([`AnyTransform`], [`AnyArena`], [`AnyScratch`], [`AnyArenaPool`],
//! [`AnyPlanner`]) that let one serving plane run `f64`/`f32`/`bf16`/
//! `fp16` transforms side by side.
//!
//! The paper's headline claim is about *half precision*: dual-select's
//! bounded ratios give fp16 FFTs a 235× tighter cumulative error bound
//! than clamped Linzer–Feig.  The typed core ([`Transform<T>`]) has
//! carried that result since the seed, but a serving plane cannot be
//! generic over `T` — requests pick their precision at run time.  This
//! module erases the dtype exactly once, at the enum boundary:
//!
//! ```text
//!   PlanSpec::new(n).strategy(..).dtype(DType::F16)
//!        .build_any()?            -> AnyTransform   (enum of Arc<dyn Transform<T>>)
//!
//!   AnyPlanner::get(spec)?        same, cached — the cache key is the
//!                                 full PlanSpec, dtype included
//!
//!   AnyArena::new(dtype, n)       dtype-tagged planar frame storage;
//!     .push_frame_f64(re, im)     f64 payloads round ONCE into the
//!                                 working precision (same policy as
//!                                 the twiddle tables)
//!
//!   t.execute_many_any(&mut arena, &mut scratch)?
//!                                 dispatches to the typed kernel; a
//!                                 dtype mismatch is a typed error,
//!                                 never a silent cast
//! ```
//!
//! Inside each enum arm the full monomorphized kernel runs — the
//! `DType::F32` path executes the *same machine code* as the typed
//! `Transform<f32>` path, bit for bit (asserted by the
//! `dtype_api` regression test).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, PoisonError};

use crate::fixed::{FixedArena, FixedFrameRef, FixedPlan, FixedScratch};
use crate::precision::{Bf16, F16, Real};

use super::super::{Direction, Strategy};
use super::batch::{FrameArena, Scratch};
use super::error::{FftError, FftResult};
use super::spec::PlanSpec;
use super::transform::Transform;

/// A runtime description of the working precision — the serving
/// plane's wire-level dtype tag.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    /// IEEE 754 binary64 (hardware).
    F64,
    /// IEEE 754 binary32 (hardware; the serving default).
    #[default]
    F32,
    /// bfloat16 (software, single-rounding semantics).
    Bf16,
    /// IEEE 754 binary16 (software, single-rounding semantics) — the
    /// precision the paper's headline bound is about.
    F16,
    /// Q15 fixed point (`i16` codes, block-floating-point frames).
    I16,
    /// Q31 fixed point (`i32` codes, block-floating-point frames).
    I32,
}

impl DType {
    /// Every supported dtype, in [`DType::index`] order.
    pub const ALL: [DType; 6] = [
        DType::F64,
        DType::F32,
        DType::Bf16,
        DType::F16,
        DType::I16,
        DType::I32,
    ];

    /// Number of supported dtypes — the length of per-dtype tables
    /// indexed by [`DType::index`].
    pub const COUNT: usize = Self::ALL.len();

    /// The floating-point dtypes only — the ones with a typed
    /// [`Real`] working precision and an eq. (11)-style a-priori
    /// bound.  Fixed-point dtypes instead carry a signal-dependent
    /// quantization bound per frame.
    pub const FLOATS: [DType; 4] = [DType::F64, DType::F32, DType::Bf16, DType::F16];

    /// Wire/CLI name (`"f64" | "f32" | "bf16" | "f16" | "i16" | "i32"`).
    pub fn name(self) -> &'static str {
        match self {
            DType::F64 => "f64",
            DType::F32 => "f32",
            DType::Bf16 => "bf16",
            DType::F16 => "f16",
            DType::I16 => "i16",
            DType::I32 => "i32",
        }
    }

    /// Dense index into per-dtype tables (`[0, COUNT)`, matching
    /// [`DType::ALL`]).
    pub fn index(self) -> usize {
        match self {
            DType::F64 => 0,
            DType::F32 => 1,
            DType::Bf16 => 2,
            DType::F16 => 3,
            DType::I16 => 4,
            DType::I32 => 5,
        }
    }

    /// True for the quantized integer dtypes (block-floating-point
    /// frames, signal-dependent bounds, dual-select only).
    pub fn is_fixed(self) -> bool {
        matches!(self, DType::I16 | DType::I32)
    }

    /// Quantization step of the format at unit scale: the unit
    /// roundoff (the `eps` in the paper's error bounds — 4.88e-4 for
    /// f16, 5.96e-8 for f32) for floats, and the Q-format quantum
    /// (`2^-15` / `2^-31`) for fixed point.  Fixed-point quanta are
    /// *absolute* steps at block scale 0, not relative roundoffs — do
    /// not feed them to the eq. (11) float bound chain; the fixed
    /// plane attaches its own per-frame bound instead.
    pub fn unit_roundoff(self) -> f64 {
        match self {
            DType::F64 => <f64 as Real>::EPSILON,
            DType::F32 => <f32 as Real>::EPSILON,
            DType::Bf16 => <Bf16 as Real>::EPSILON,
            DType::F16 => <F16 as Real>::EPSILON,
            DType::I16 => (-15f64).exp2(),
            DType::I32 => (-31f64).exp2(),
        }
    }

    /// The dtype of a typed [`Real`] working precision, if it is one
    /// of the float wire dtypes.  `None` for downstream [`Real`]
    /// implementations the wire format does not know about (the trait
    /// is public and unsealed) — such types still work through the
    /// typed API, they just have no dtype-erased spelling.
    pub fn try_of<T: Real>() -> Option<DType> {
        match T::NAME {
            "f64" => Some(DType::F64),
            "f32" => Some(DType::F32),
            "bf16" => Some(DType::Bf16),
            "fp16" => Some(DType::F16),
            _ => None,
        }
    }

    /// The dtype of one of the four built-in [`Real`] precisions;
    /// panics for foreign `Real` implementations (use
    /// [`DType::try_of`] when `T` may come from downstream).
    pub fn of<T: Real>() -> DType {
        Self::try_of::<T>()
            .unwrap_or_else(|| panic!("Real impl {:?} has no wire dtype", T::NAME))
    }
}

impl core::fmt::Display for DType {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        f.write_str(self.name())
    }
}

impl core::str::FromStr for DType {
    type Err = FftError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f64" => Ok(DType::F64),
            "f32" => Ok(DType::F32),
            "bf16" => Ok(DType::Bf16),
            "f16" | "fp16" | "half" => Ok(DType::F16),
            "i16" | "q15" => Ok(DType::I16),
            "i32" | "q31" => Ok(DType::I32),
            other => Err(FftError::InvalidArgument(format!(
                "unknown dtype {other:?} (expected f64|f32|bf16|f16|i16|i32)"
            ))),
        }
    }
}

/// Dispatch a generic expression over every [`AnyArena`] variant —
/// float ([`FrameArena`]) and fixed ([`FixedArena`]) alike, so the
/// body may only use their shared storage surface.
macro_rules! each_arena {
    ($value:expr, $a:ident => $body:expr) => {
        match $value {
            AnyArena::F64($a) => $body,
            AnyArena::F32($a) => $body,
            AnyArena::Bf16($a) => $body,
            AnyArena::F16($a) => $body,
            AnyArena::I16($a) => $body,
            AnyArena::I32($a) => $body,
        }
    };
}

/// Dispatch a generic expression over every [`AnyTransform`] variant.
macro_rules! each_transform {
    ($value:expr, $t:ident => $body:expr) => {
        match $value {
            AnyTransform::F64($t) => $body,
            AnyTransform::F32($t) => $body,
            AnyTransform::Bf16($t) => $body,
            AnyTransform::F16($t) => $body,
            AnyTransform::I16($t) => $body,
            AnyTransform::I32($t) => $body,
        }
    };
}

/// Dtype-tagged planar frame storage: a [`FrameArena`] whose element
/// type is chosen at run time.
///
/// Ingest policy (identical to the twiddle tables, see
/// [`crate::fft::twiddle`]): payloads arrive as f64 and are rounded
/// **once** into the working precision by
/// [`AnyArena::push_frame_f64`] — never through an intermediate
/// format.
#[derive(Clone, Debug, PartialEq)]
pub enum AnyArena {
    F64(FrameArena<f64>),
    F32(FrameArena<f32>),
    Bf16(FrameArena<Bf16>),
    F16(FrameArena<F16>),
    /// Q15 block-floating-point frames (quantized plane).
    I16(FixedArena<i16>),
    /// Q31 block-floating-point frames (quantized plane).
    I32(FixedArena<i32>),
}

impl AnyArena {
    /// An empty arena of `dtype` for frames of `frame_len` samples.
    pub fn new(dtype: DType, frame_len: usize) -> Self {
        match dtype {
            DType::F64 => AnyArena::F64(FrameArena::new(frame_len)),
            DType::F32 => AnyArena::F32(FrameArena::new(frame_len)),
            DType::Bf16 => AnyArena::Bf16(FrameArena::new(frame_len)),
            DType::F16 => AnyArena::F16(FrameArena::new(frame_len)),
            DType::I16 => AnyArena::I16(FixedArena::new(frame_len)),
            DType::I32 => AnyArena::I32(FixedArena::new(frame_len)),
        }
    }

    /// The element dtype this arena stores.
    pub fn dtype(&self) -> DType {
        match self {
            AnyArena::F64(_) => DType::F64,
            AnyArena::F32(_) => DType::F32,
            AnyArena::Bf16(_) => DType::Bf16,
            AnyArena::F16(_) => DType::F16,
            AnyArena::I16(_) => DType::I16,
            AnyArena::I32(_) => DType::I32,
        }
    }

    /// Samples per frame.
    pub fn frame_len(&self) -> usize {
        each_arena!(self, a => a.frame_len())
    }

    /// Number of frames currently stored.
    pub fn frames(&self) -> usize {
        each_arena!(self, a => a.frames())
    }

    pub fn is_empty(&self) -> bool {
        self.frames() == 0
    }

    /// Ensure room for `frames` frames total.
    pub fn reserve_frames(&mut self, frames: usize) {
        each_arena!(self, a => a.reserve_frames(frames))
    }

    /// Drop all frames and re-purpose for `frame_len`, keeping the
    /// allocation and the dtype — the recycle path of [`AnyArenaPool`].
    pub fn reset(&mut self, frame_len: usize) {
        each_arena!(self, a => a.reset(frame_len))
    }

    /// Append a zeroed frame; returns its index.
    pub fn push_zeroed(&mut self) -> usize {
        each_arena!(self, a => a.push_zeroed())
    }

    /// Append a frame from split f64 payloads, rounding into the
    /// working precision in one pass; returns the frame index.
    pub fn push_frame_f64(&mut self, re: &[f64], im: &[f64]) -> usize {
        each_arena!(self, a => a.push_frame_f64(re, im))
    }

    /// Copy frame `i` out, widened to f64 (exact for every supported
    /// format — float codes widen losslessly, fixed codes dequantize
    /// as `q · 2^scale`, also exact).
    pub fn frame_f64(&self, i: usize) -> (Vec<f64>, Vec<f64>) {
        let (mut re, mut im) = (Vec::new(), Vec::new());
        self.frame_f64_into(i, &mut re, &mut im);
        (re, im)
    }

    /// Append frame `i` to caller-held vectors, widened to f64 — the
    /// allocation-free spelling of [`AnyArena::frame_f64`], used by
    /// the streaming/graph hot paths (same exactness guarantees).
    pub fn frame_f64_into(&self, i: usize, out_re: &mut Vec<f64>, out_im: &mut Vec<f64>) {
        macro_rules! widen {
            ($a:expr) => {{
                let (re, im) = $a.frame(i);
                out_re.extend(re.iter().map(|&x| x.to_f64()));
                out_im.extend(im.iter().map(|&x| x.to_f64()));
            }};
        }
        match self {
            AnyArena::F64(a) => widen!(a),
            AnyArena::F32(a) => widen!(a),
            AnyArena::Bf16(a) => widen!(a),
            AnyArena::F16(a) => widen!(a),
            AnyArena::I16(a) => a.frame_f64_into(i, out_re, out_im),
            AnyArena::I32(a) => a.frame_f64_into(i, out_re, out_im),
        }
    }

    /// The a-priori relative error bound frame `i` carries, when the
    /// arena is fixed point and the frame has been transformed.
    /// Always `None` for float arenas — their bound is the dtype-level
    /// eq. (11) result, not per-frame state.
    pub fn frame_bound(&self, i: usize) -> Option<f64> {
        match self {
            AnyArena::I16(a) => a.frame_bound(i),
            AnyArena::I32(a) => a.frame_bound(i),
            _ => None,
        }
    }

    /// Quantizer saturation events counted while this arena's frames
    /// were ingested (since its last clear/reset).  Always 0 for float
    /// arenas — rounding into a float format never clamps.
    pub fn saturations(&self) -> u64 {
        match self {
            AnyArena::I16(a) => a.saturations(),
            AnyArena::I32(a) => a.saturations(),
            _ => 0,
        }
    }

    /// Borrow frame `i` as quantized codes plus block-floating-point
    /// metadata — the wire encoder's zero-copy read path.  `None` for
    /// float arenas.
    pub fn fixed_frame(&self, i: usize) -> Option<FixedFrameRef<'_>> {
        match self {
            AnyArena::I16(a) => {
                let meta = a.meta(i);
                let (re, im) = a.frame(i);
                Some(FixedFrameRef::I16 { scale: meta.scale, bound: meta.bound, re, im })
            }
            AnyArena::I32(a) => {
                let meta = a.meta(i);
                let (re, im) = a.frame(i);
                Some(FixedFrameRef::I32 { scale: meta.scale, bound: meta.bound, re, im })
            }
            _ => None,
        }
    }

    /// The typed f32 arena, when that is what this is (the zero-copy
    /// response fast path).
    pub fn as_f32(&self) -> Option<&FrameArena<f32>> {
        match self {
            AnyArena::F32(a) => Some(a),
            _ => None,
        }
    }
}

impl From<FrameArena<f64>> for AnyArena {
    fn from(a: FrameArena<f64>) -> Self {
        AnyArena::F64(a)
    }
}
impl From<FrameArena<f32>> for AnyArena {
    fn from(a: FrameArena<f32>) -> Self {
        AnyArena::F32(a)
    }
}
impl From<FrameArena<Bf16>> for AnyArena {
    fn from(a: FrameArena<Bf16>) -> Self {
        AnyArena::Bf16(a)
    }
}
impl From<FrameArena<F16>> for AnyArena {
    fn from(a: FrameArena<F16>) -> Self {
        AnyArena::F16(a)
    }
}
impl From<FixedArena<i16>> for AnyArena {
    fn from(a: FixedArena<i16>) -> Self {
        AnyArena::I16(a)
    }
}
impl From<FixedArena<i32>> for AnyArena {
    fn from(a: FixedArena<i32>) -> Self {
        AnyArena::I32(a)
    }
}

/// Per-worker scratch pools, one per dtype.  Each typed pool amortizes
/// independently, so a worker serving mixed-precision traffic is still
/// allocation-free once every dtype it has seen is warm.
#[derive(Debug, Default)]
pub struct AnyScratch {
    pub for_f64: Scratch<f64>,
    pub for_f32: Scratch<f32>,
    pub for_bf16: Scratch<Bf16>,
    pub for_f16: Scratch<F16>,
    pub for_i16: FixedScratch<i16>,
    pub for_i32: FixedScratch<i32>,
}

impl AnyScratch {
    pub fn new() -> Self {
        AnyScratch::default()
    }

    /// Total pool misses (allocations) across all dtypes — flat after
    /// warmup, asserted by the allocation regression test.
    pub fn misses(&self) -> u64 {
        self.for_f64.misses()
            + self.for_f32.misses()
            + self.for_bf16.misses()
            + self.for_f16.misses()
            + self.for_i16.misses()
            + self.for_i32.misses()
    }

    /// Total `take` calls served across all dtypes.
    pub fn takes(&self) -> u64 {
        self.for_f64.takes()
            + self.for_f32.takes()
            + self.for_bf16.takes()
            + self.for_f16.takes()
            + self.for_i16.takes()
            + self.for_i32.takes()
    }
}

/// A dtype-erased planned transform: an enum of typed
/// `Arc<dyn Transform<T>>`, cheap to clone and [`Send`]/[`Sync`] like
/// its contents.
///
/// Execution dispatches once per *batch* (not per sample): inside each
/// arm the fully monomorphized typed kernel runs, so erasure costs one
/// match per call.
#[derive(Clone, Debug)]
pub enum AnyTransform {
    F64(Arc<dyn Transform<f64>>),
    F32(Arc<dyn Transform<f32>>),
    Bf16(Arc<dyn Transform<Bf16>>),
    F16(Arc<dyn Transform<F16>>),
    /// Q15 block-floating-point Stockham plan (dual-select only).
    I16(Arc<FixedPlan<i16>>),
    /// Q31 block-floating-point Stockham plan (dual-select only).
    I32(Arc<FixedPlan<i32>>),
}

impl AnyTransform {
    /// The working precision this transform computes in.
    pub fn dtype(&self) -> DType {
        match self {
            AnyTransform::F64(_) => DType::F64,
            AnyTransform::F32(_) => DType::F32,
            AnyTransform::Bf16(_) => DType::Bf16,
            AnyTransform::F16(_) => DType::F16,
            AnyTransform::I16(_) => DType::I16,
            AnyTransform::I32(_) => DType::I32,
        }
    }

    /// Logical frame length (number of complex samples per execute).
    pub fn len(&self) -> usize {
        each_transform!(self, t => t.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Butterfly strategy baked into the plan's tables.
    pub fn strategy(&self) -> Strategy {
        each_transform!(self, t => t.strategy())
    }

    /// Transform direction.
    pub fn direction(&self) -> Direction {
        each_transform!(self, t => t.direction())
    }

    /// Execute every frame of `arena` in place, drawing working
    /// buffers from the matching per-dtype pool of `scratch` — the
    /// dtype-erased serving hot path.
    ///
    /// A dtype mismatch between transform and arena is a typed
    /// [`FftError::DTypeMismatch`], never a silent cast.
    pub fn execute_many_any(
        &self,
        arena: &mut AnyArena,
        scratch: &mut AnyScratch,
    ) -> FftResult<()> {
        match (self, arena) {
            (AnyTransform::F64(t), AnyArena::F64(a)) => {
                t.execute_many(a.view_mut(), &mut scratch.for_f64);
                Ok(())
            }
            (AnyTransform::F32(t), AnyArena::F32(a)) => {
                t.execute_many(a.view_mut(), &mut scratch.for_f32);
                Ok(())
            }
            (AnyTransform::Bf16(t), AnyArena::Bf16(a)) => {
                t.execute_many(a.view_mut(), &mut scratch.for_bf16);
                Ok(())
            }
            (AnyTransform::F16(t), AnyArena::F16(a)) => {
                t.execute_many(a.view_mut(), &mut scratch.for_f16);
                Ok(())
            }
            (AnyTransform::I16(t), AnyArena::I16(a)) => {
                t.execute_many(a, &mut scratch.for_i16);
                Ok(())
            }
            (AnyTransform::I32(t), AnyArena::I32(a)) => {
                t.execute_many(a, &mut scratch.for_i32);
                Ok(())
            }
            (t, a) => Err(FftError::DTypeMismatch { expected: t.dtype(), got: a.dtype() }),
        }
    }

    /// Execute a single frame of `arena` in place (same dispatch and
    /// mismatch semantics as [`AnyTransform::execute_many_any`]).
    pub fn execute_frame_any(
        &self,
        arena: &mut AnyArena,
        frame: usize,
        scratch: &mut AnyScratch,
    ) -> FftResult<()> {
        match (self, arena) {
            (AnyTransform::F64(t), AnyArena::F64(a)) => {
                let (re, im) = a.frame_mut(frame);
                t.execute_frame(re, im, &mut scratch.for_f64);
                Ok(())
            }
            (AnyTransform::F32(t), AnyArena::F32(a)) => {
                let (re, im) = a.frame_mut(frame);
                t.execute_frame(re, im, &mut scratch.for_f32);
                Ok(())
            }
            (AnyTransform::Bf16(t), AnyArena::Bf16(a)) => {
                let (re, im) = a.frame_mut(frame);
                t.execute_frame(re, im, &mut scratch.for_bf16);
                Ok(())
            }
            (AnyTransform::F16(t), AnyArena::F16(a)) => {
                let (re, im) = a.frame_mut(frame);
                t.execute_frame(re, im, &mut scratch.for_f16);
                Ok(())
            }
            (AnyTransform::I16(t), AnyArena::I16(a)) => {
                t.execute_frame(a, frame, &mut scratch.for_i16);
                Ok(())
            }
            (AnyTransform::I32(t), AnyArena::I32(a)) => {
                t.execute_frame(a, frame, &mut scratch.for_i32);
                Ok(())
            }
            (t, a) => Err(FftError::DTypeMismatch { expected: t.dtype(), got: a.dtype() }),
        }
    }
}

/// Shared recycler for [`AnyArena`]s travelling through the serving
/// plane inside `Arc`s — the dtype-aware sibling of
/// [`super::batch::ArenaPool`].  `take` reclaims a parked arena only
/// when its dtype matches and every response handle has been dropped
/// (refcount 1), so an f16 batch never inherits f32 storage.
#[derive(Debug, Default)]
pub struct AnyArenaPool {
    parked: Mutex<Vec<Arc<AnyArena>>>,
}

/// Cap on parked arenas; beyond this, recycled arenas are dropped
/// (bounds memory if clients hold responses for a long time).
const ANY_ARENA_POOL_CAP: usize = 64;

impl AnyArenaPool {
    pub fn new() -> Self {
        AnyArenaPool { parked: Mutex::new(Vec::new()) }
    }

    /// Take an arena of `dtype` configured for `frame_len`, reusing a
    /// parked same-dtype arena whose clients have all dropped their
    /// handles.
    pub fn take(&self, dtype: DType, frame_len: usize) -> AnyArena {
        let mut parked = self.parked.lock().unwrap_or_else(PoisonError::into_inner);
        let mut i = 0;
        while i < parked.len() {
            if parked[i].dtype() == dtype && Arc::strong_count(&parked[i]) == 1 {
                let arc = parked.swap_remove(i);
                // The pool lock is held and the parked Vec owned the
                // only handle, so no new clone can appear between the
                // strong_count check and the unwrap.
                let mut arena = Arc::try_unwrap(arc).unwrap_or_else(|_| {
                    unreachable!("sole Arc handle observed under the pool lock")
                });
                arena.reset(frame_len);
                return arena;
            }
            i += 1;
        }
        AnyArena::new(dtype, frame_len)
    }

    /// Park a shared arena for future reclamation.
    pub fn recycle(&self, arena: Arc<AnyArena>) {
        let mut parked = self.parked.lock().unwrap_or_else(PoisonError::into_inner);
        if parked.len() < ANY_ARENA_POOL_CAP {
            parked.push(arena);
        }
    }

    /// Arenas currently parked (in any refcount state).
    pub fn parked(&self) -> usize {
        self.parked
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }
}

/// Thread-safe dtype-erased plan cache, keyed by the full [`PlanSpec`]
/// — dtype included, so `(PlanSpec, DType)` pairs cache independently.
/// Same poison-recovery policy as the typed [`super::Planner`].
#[derive(Default)]
pub struct AnyPlanner {
    cache: Mutex<HashMap<PlanSpec, AnyTransform>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl AnyPlanner {
    pub fn new() -> Self {
        AnyPlanner::default()
    }

    /// Fetch or build the transform described by `spec` in
    /// `spec.dtype`.
    pub fn get(&self, spec: PlanSpec) -> FftResult<AnyTransform> {
        self.get_tracked(spec).map(|(t, _)| t)
    }

    /// [`AnyPlanner::get`], also reporting whether the lookup was a
    /// cache hit (`true`) or had to build the plan (`false`) — the
    /// serving plane feeds this into its metrics.
    pub fn get_tracked(&self, spec: PlanSpec) -> FftResult<(AnyTransform, bool)> {
        let mut cache = self.cache.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(t) = cache.get(&spec) {
            self.hits.fetch_add(1, AtomicOrdering::Relaxed);
            return Ok((t.clone(), true));
        }
        let built = spec.build_any()?;
        cache.insert(spec, built.clone());
        self.misses.fetch_add(1, AtomicOrdering::Relaxed);
        Ok((built, false))
    }

    /// Fetch or build a complex transform for `(n, strategy,
    /// direction, dtype)` — the serving plane's lookup shape.
    pub fn plan(
        &self,
        n: usize,
        strategy: Strategy,
        direction: Direction,
        dtype: DType,
    ) -> FftResult<AnyTransform> {
        self.get(
            PlanSpec::new(n)
                .strategy(strategy)
                .direction(direction)
                .dtype(dtype),
        )
    }

    /// [`AnyPlanner::plan`] with hit/miss tracking.
    pub fn plan_tracked(
        &self,
        n: usize,
        strategy: Strategy,
        direction: Direction,
        dtype: DType,
    ) -> FftResult<(AnyTransform, bool)> {
        self.get_tracked(
            PlanSpec::new(n)
                .strategy(strategy)
                .direction(direction)
                .dtype(dtype),
        )
    }

    /// Lookups served from cache since construction.
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(AtomicOrdering::Relaxed)
    }

    /// Lookups that had to build a plan (failed builds not counted).
    pub fn cache_misses(&self) -> u64 {
        self.misses.load(AtomicOrdering::Relaxed)
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    #[test]
    fn dtype_parse_display_unit_roundoff() {
        for d in DType::ALL {
            assert_eq!(d.name().parse::<DType>().unwrap(), d);
            assert_eq!(d.to_string(), d.name());
            assert_eq!(DType::ALL[d.index()], d);
        }
        assert_eq!(DType::COUNT, DType::ALL.len());
        assert_eq!("fp16".parse::<DType>().unwrap(), DType::F16);
        assert_eq!("q15".parse::<DType>().unwrap(), DType::I16);
        assert_eq!("q31".parse::<DType>().unwrap(), DType::I32);
        assert!("f8".parse::<DType>().is_err());
        assert_eq!(DType::F16.unit_roundoff(), 4.8828125e-4);
        // Fixed-point quanta are the exact Q-format steps.
        assert_eq!(DType::I16.unit_roundoff(), 3.0517578125e-5);
        assert_eq!(DType::I32.unit_roundoff(), 4.656612873077393e-10);
        for d in DType::FLOATS {
            assert!(!d.is_fixed(), "{d}");
        }
        assert!(DType::I16.is_fixed() && DType::I32.is_fixed());
        assert_eq!(DType::default(), DType::F32);
        assert_eq!(DType::of::<f32>(), DType::F32);
        assert_eq!(DType::of::<F16>(), DType::F16);
        assert_eq!(DType::of::<Bf16>(), DType::Bf16);
        assert_eq!(DType::of::<f64>(), DType::F64);
    }

    #[test]
    fn any_arena_rounds_once_and_widens_exactly() {
        for dtype in DType::ALL {
            let mut a = AnyArena::new(dtype, 4);
            assert_eq!(a.dtype(), dtype);
            // Values exactly representable in every format.
            a.push_frame_f64(&[1.0, -0.5, 2.0, 0.0], &[0.25, 1.0, -1.0, 4.0]);
            assert_eq!(a.frames(), 1);
            assert_eq!(a.frame_len(), 4);
            let (re, im) = a.frame_f64(0);
            assert_eq!(re, vec![1.0, -0.5, 2.0, 0.0], "{dtype}");
            assert_eq!(im, vec![0.25, 1.0, -1.0, 4.0], "{dtype}");
        }
        // Rounding happens (once) for values outside the format.
        let mut h = AnyArena::new(DType::F16, 1);
        h.push_frame_f64(&[1.0 + 1e-6], &[0.0]);
        assert_eq!(h.frame_f64(0).0, vec![1.0]);
    }

    #[test]
    fn any_transform_executes_each_dtype() {
        let n = 64;
        let mut rng = Pcg32::seed(5);
        let re: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let (wr, wi) = crate::dft::naive_dft(&re, &im, false);
        for dtype in DType::ALL {
            let t = PlanSpec::new(n)
                .strategy(Strategy::DualSelect)
                .dtype(dtype)
                .build_any()
                .unwrap();
            assert_eq!(t.dtype(), dtype);
            assert_eq!(t.len(), n);
            assert_eq!(t.strategy(), Strategy::DualSelect);
            assert_eq!(t.direction(), Direction::Forward);
            let mut arena = AnyArena::new(dtype, n);
            arena.push_frame_f64(&re, &im);
            let mut scratch = AnyScratch::new();
            t.execute_many_any(&mut arena, &mut scratch).unwrap();
            let (gr, gi) = arena.frame_f64(0);
            let err = rel_l2(&gr, &gi, &wr, &wi);
            // Floats: coarse per-dtype sanity (exact bound checks live
            // in the analysis tests and the coordinator integration
            // tests).  Fixed point: the frame's own attached a-priori
            // bound IS the contract.
            let tol = if dtype.is_fixed() {
                arena.frame_bound(0).expect("fixed frame carries a bound after execute")
            } else {
                assert_eq!(arena.frame_bound(0), None);
                100.0 * dtype.unit_roundoff()
            };
            assert!(err < tol, "{dtype} err {err:.3e} tol {tol:.3e}");
        }
    }

    #[test]
    fn execute_frame_any_matches_execute_many_any() {
        let n = 32;
        let t = PlanSpec::new(n).dtype(DType::F16).build_any().unwrap();
        let mut rng = Pcg32::seed(9);
        let re: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut a = AnyArena::new(DType::F16, n);
        let mut b = AnyArena::new(DType::F16, n);
        a.push_frame_f64(&re, &im);
        b.push_frame_f64(&re, &im);
        let mut scratch = AnyScratch::new();
        t.execute_many_any(&mut a, &mut scratch).unwrap();
        t.execute_frame_any(&mut b, 0, &mut scratch).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dtype_mismatch_is_a_typed_error() {
        let t = PlanSpec::new(8).dtype(DType::F16).build_any().unwrap();
        let mut arena = AnyArena::new(DType::F32, 8);
        arena.push_zeroed();
        let mut scratch = AnyScratch::new();
        let err = t.execute_many_any(&mut arena, &mut scratch).unwrap_err();
        assert_eq!(
            err,
            FftError::DTypeMismatch { expected: DType::F16, got: DType::F32 }
        );
        assert!(err.to_string().contains("dtype mismatch"), "{err}");
        let err2 = t.execute_frame_any(&mut arena, 0, &mut scratch).unwrap_err();
        assert_eq!(err, err2);
    }

    #[test]
    fn any_planner_caches_per_dtype() {
        let planner = AnyPlanner::new();
        let spec = PlanSpec::new(64).strategy(Strategy::DualSelect);
        for dtype in DType::ALL {
            planner.get(spec.dtype(dtype)).unwrap();
        }
        assert_eq!(planner.len(), DType::COUNT);
        // Same (spec, dtype): served from cache, count unchanged.
        planner.get(spec.dtype(DType::F16)).unwrap();
        planner.get(spec.dtype(DType::I16)).unwrap();
        assert_eq!(planner.len(), DType::COUNT);
        // plan() is the (n, strategy, direction, dtype) spelling.
        planner
            .plan(64, Strategy::DualSelect, Direction::Inverse, DType::F16)
            .unwrap();
        assert_eq!(planner.len(), DType::COUNT + 1);
        // Build errors are not cached.
        assert!(planner.get(PlanSpec::new(100).stockham()).is_err());
        assert!(planner.get(spec.strategy(Strategy::LinzerFeig).dtype(DType::I16)).is_err());
        assert_eq!(planner.len(), DType::COUNT + 1);
    }

    #[test]
    fn any_arena_pool_matches_dtype_and_refcount() {
        let pool = AnyArenaPool::new();
        let mut a = pool.take(DType::F16, 8);
        for _ in 0..4 {
            a.push_zeroed();
        }
        a.reserve_frames(16);
        let shared = Arc::new(a);
        let client = shared.clone();
        pool.recycle(shared);
        // Client still holds a handle: not reclaimable.
        assert_eq!(pool.take(DType::F16, 8).frames(), 0);
        drop(client);
        // An f32 request must NOT steal the parked f16 arena.
        let f32_arena = pool.take(DType::F32, 8);
        assert_eq!(f32_arena.dtype(), DType::F32);
        assert_eq!(pool.parked(), 1);
        // A matching f16 request reclaims it (reset, allocation kept).
        let reused = pool.take(DType::F16, 8);
        assert_eq!(reused.dtype(), DType::F16);
        assert_eq!(reused.frames(), 0);
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn any_scratch_pools_amortize_per_dtype() {
        let n = 64;
        let mut scratch = AnyScratch::new();
        for dtype in DType::ALL {
            let t = PlanSpec::new(n).dtype(dtype).build_any().unwrap();
            let mut arena = AnyArena::new(dtype, n);
            for _ in 0..4 {
                arena.push_zeroed();
            }
            t.execute_many_any(&mut arena, &mut scratch).unwrap();
            let warm = scratch.misses();
            t.execute_many_any(&mut arena, &mut scratch).unwrap();
            t.execute_many_any(&mut arena, &mut scratch).unwrap();
            assert_eq!(scratch.misses(), warm, "{dtype} pool kept allocating");
        }
        assert!(scratch.takes() > 0);
    }
}
