//! The crate-wide typed error: every fallible operation in the FFT
//! core, the signal pipelines, the runtime and the serving plane
//! returns [`FftError`] (no more stringly-typed `Result<_, String>`).
//!
//! The taxonomy mirrors where things can go wrong:
//!
//! * plan construction — [`FftError::NonPowerOfTwo`],
//!   [`FftError::InvalidSize`], [`FftError::UnsupportedStrategy`],
//!   [`FftError::Unsupported`]
//! * data shape — [`FftError::LengthMismatch`],
//!   [`FftError::DTypeMismatch`]
//! * user input (CLI / spec parsing) — [`FftError::UnknownStrategy`],
//!   [`FftError::InvalidArgument`]
//! * serving plane — [`FftError::Rejected`], [`FftError::ChannelClosed`],
//!   [`FftError::Poisoned`]
//! * network plane (wire codec) — [`FftError::Protocol`]
//! * compute backends — [`FftError::Backend`]

use core::fmt;

use crate::fft::Strategy;

use super::dtype::DType;

/// Shorthand used across the crate.
pub type FftResult<T> = Result<T, FftError>;

/// Everything that can go wrong planning or serving a transform.
#[derive(Clone, Debug, PartialEq)]
pub enum FftError {
    /// The requested size is not the power of two the algorithm needs.
    NonPowerOfTwo { n: usize },
    /// The requested size is invalid for the chosen transform kind.
    InvalidSize { n: usize, reason: &'static str },
    /// Input length does not match what the plan was built for.
    LengthMismatch { expected: usize, got: usize },
    /// A dtype-erased execute was handed buffers of a different
    /// working precision than the transform computes in.
    DTypeMismatch { expected: DType, got: DType },
    /// The chosen (algorithm, strategy) combination is not available.
    UnsupportedStrategy { strategy: Strategy, reason: &'static str },
    /// The operation has no implementation in this build.
    Unsupported(&'static str),
    /// A strategy name that did not parse.
    UnknownStrategy(String),
    /// A malformed CLI argument or spec field.
    InvalidArgument(String),
    /// A shared lock was poisoned by a panicking thread and the
    /// operation chose not to continue over the poisoned state.
    Poisoned(&'static str),
    /// A compute backend (PJRT runtime, artifact manifest, worker
    /// thread spawn) failed.
    Backend(String),
    /// A malformed or incompatible frame on the network plane: bad
    /// magic, failed header checksum, unknown version, unknown
    /// op/strategy/dtype/status tag, an oversized or inconsistent
    /// length, or a stream truncated mid-frame (see `PROTOCOL.md`).
    Protocol(String),
    /// Admission control rejected the request (backpressure).
    Rejected { in_flight: usize, limit: usize },
    /// The server (or a response channel) has shut down.
    ChannelClosed(&'static str),
    /// A paper-invariant audit failed (CLI `audit` command).
    AuditFailed { strategy: Strategy },
}

impl fmt::Display for FftError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FftError::NonPowerOfTwo { n } => {
                write!(f, "FFT size must be a power of two >= 2, got {n}")
            }
            FftError::InvalidSize { n, reason } => write!(f, "{reason}, got {n}"),
            FftError::LengthMismatch { expected, got } => {
                write!(f, "length mismatch: expected {expected}, got {got}")
            }
            FftError::DTypeMismatch { expected, got } => {
                write!(f, "dtype mismatch: transform computes in {expected}, buffers are {got}")
            }
            FftError::UnsupportedStrategy { strategy, reason } => {
                write!(f, "strategy {strategy} unsupported: {reason}")
            }
            FftError::Unsupported(what) => write!(f, "unsupported: {what}"),
            FftError::UnknownStrategy(s) => {
                write!(f, "unknown strategy {s:?} (expected standard|lf|cos|dual)")
            }
            FftError::InvalidArgument(msg) => f.write_str(msg),
            FftError::Poisoned(what) => {
                write!(f, "lock poisoned by a panicked thread: {what}")
            }
            FftError::Backend(msg) => f.write_str(msg),
            FftError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            FftError::Rejected { in_flight, limit } => {
                write!(f, "rejected: {in_flight} requests in flight (limit {limit})")
            }
            FftError::ChannelClosed(what) => write!(f, "channel closed: {what}"),
            FftError::AuditFailed { strategy } => {
                write!(f, "{} audit failed (paper invariant violated)", strategy.name())
            }
        }
    }
}

impl std::error::Error for FftError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        assert_eq!(
            FftError::NonPowerOfTwo { n: 768 }.to_string(),
            "FFT size must be a power of two >= 2, got 768"
        );
        assert!(FftError::Rejected { in_flight: 4, limit: 4 }
            .to_string()
            .contains("rejected"));
        assert!(FftError::LengthMismatch { expected: 8, got: 4 }
            .to_string()
            .contains("expected 8"));
        assert_eq!(
            FftError::Protocol("bad magic".into()).to_string(),
            "protocol error: bad magic"
        );
    }

    #[test]
    fn error_trait_object_works() {
        let e: Box<dyn std::error::Error> = Box::new(FftError::Unsupported("x"));
        assert_eq!(e.to_string(), "unsupported: x");
    }

    #[test]
    fn errors_are_comparable() {
        assert_eq!(
            FftError::NonPowerOfTwo { n: 3 },
            FftError::NonPowerOfTwo { n: 3 }
        );
        assert_ne!(
            FftError::NonPowerOfTwo { n: 3 },
            FftError::NonPowerOfTwo { n: 5 }
        );
    }
}
