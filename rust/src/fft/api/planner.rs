//! The generalized [`Planner`]: an FFTW-style cache keyed by
//! [`PlanSpec`], holding *every* plan kind (complex radix-2/4, DIT,
//! Bluestein, real-input) behind `Arc<dyn Transform<T>>` so the
//! coordinator's worker threads share tables without copying.
//!
//! The cache mutex uses poison *recovery*: a worker that panics while
//! holding the lock leaves a fully valid `HashMap` behind (plans are
//! immutable once inserted, and `HashMap::insert`/`get` keep the map
//! valid), so other workers continue over the poisoned state instead
//! of wedging the serving plane.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, PoisonError};

use crate::precision::Real;

use super::super::{Direction, Strategy};
use super::dtype::DType;
use super::error::FftResult;
use super::spec::PlanSpec;
use super::transform::Transform;

/// Thread-safe plan cache keyed by [`PlanSpec`].
pub struct Planner<T: Real> {
    cache: Mutex<HashMap<PlanSpec, Arc<dyn Transform<T>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<T: Real> Default for Planner<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Real> Planner<T> {
    pub fn new() -> Self {
        Planner {
            cache: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch or build the transform described by `spec`.
    ///
    /// The spec's `dtype` field is normalized to `T` first: a typed
    /// planner computes in exactly one precision, so specs that differ
    /// only in their (ignored) dtype tag share one cache entry.  (For
    /// a downstream `Real` impl with no wire dtype the tag is left
    /// as-is — there is nothing to normalize to.)
    pub fn get(&self, spec: PlanSpec) -> FftResult<Arc<dyn Transform<T>>> {
        self.get_tracked(spec).map(|(t, _)| t)
    }

    /// [`Planner::get`], also reporting whether the lookup was a cache
    /// hit (`true`) or had to build the plan (`false`) — the serving
    /// plane feeds this into its metrics.
    pub fn get_tracked(&self, spec: PlanSpec) -> FftResult<(Arc<dyn Transform<T>>, bool)> {
        let spec = match DType::try_of::<T>() {
            Some(dtype) => spec.dtype(dtype),
            None => spec,
        };
        let mut cache = self.cache.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(t) = cache.get(&spec) {
            self.hits.fetch_add(1, Relaxed);
            return Ok((t.clone(), true));
        }
        let built: Arc<dyn Transform<T>> = Arc::from(spec.build::<T>()?);
        cache.insert(spec, built.clone());
        self.misses.fetch_add(1, Relaxed);
        Ok((built, false))
    }

    /// Lookups served from cache since construction.
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(Relaxed)
    }

    /// Lookups that had to build a plan.  Failed builds are not
    /// counted — nothing entered the cache.
    pub fn cache_misses(&self) -> u64 {
        self.misses.load(Relaxed)
    }

    /// Fetch or build a complex transform for `(n, strategy,
    /// direction)` — the legacy `Planner::plan` shape, now routed
    /// through [`PlanSpec`] (so non-power-of-two sizes work too).
    pub fn plan(
        &self,
        n: usize,
        strategy: Strategy,
        direction: Direction,
    ) -> FftResult<Arc<dyn Transform<T>>> {
        self.get(PlanSpec::new(n).strategy(strategy).direction(direction))
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planner_caches_and_shares() {
        let planner = Planner::<f32>::new();
        let a = planner.plan(256, Strategy::DualSelect, Direction::Forward).unwrap();
        let b = planner.plan(256, Strategy::DualSelect, Direction::Forward).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(planner.len(), 1);
        let _c = planner.plan(256, Strategy::DualSelect, Direction::Inverse).unwrap();
        assert_eq!(planner.len(), 2);
        assert_eq!((planner.cache_hits(), planner.cache_misses()), (1, 2));
        let (t, hit) = planner
            .get_tracked(PlanSpec::new(256).strategy(Strategy::DualSelect))
            .unwrap();
        assert!(hit && Arc::ptr_eq(&a, &t));
        assert_eq!((planner.cache_hits(), planner.cache_misses()), (2, 2));
    }

    #[test]
    fn planner_caches_every_plan_kind() {
        let planner = Planner::<f64>::new();
        planner.get(PlanSpec::new(64)).unwrap();
        planner.get(PlanSpec::new(64).radix4()).unwrap();
        planner.get(PlanSpec::new(64).dit()).unwrap();
        planner.get(PlanSpec::new(60)).unwrap(); // Bluestein via Auto
        planner.get(PlanSpec::new(64).real_input()).unwrap();
        assert_eq!(planner.len(), 5);
        // Same spec, same Arc — regardless of kind.
        let x = planner.get(PlanSpec::new(64).radix4()).unwrap();
        let y = planner.get(PlanSpec::new(64).radix4()).unwrap();
        assert!(Arc::ptr_eq(&x, &y));
        assert_eq!(planner.len(), 5);
    }

    #[test]
    fn build_errors_are_not_cached() {
        let planner = Planner::<f32>::new();
        assert!(planner.get(PlanSpec::new(100).stockham()).is_err());
        assert!(planner.is_empty());
    }

    #[test]
    fn poisoned_cache_recovers() {
        // A thread that panics while planning must not wedge the
        // planner for everyone else (the serving plane's workers share
        // one Planner).
        let planner = Arc::new(Planner::<f32>::new());
        planner.plan(64, Strategy::DualSelect, Direction::Forward).unwrap();
        let p2 = planner.clone();
        let _ = std::thread::spawn(move || {
            let _guard = p2.cache.lock().unwrap();
            panic!("worker dies holding the cache lock");
        })
        .join();
        // The mutex is now poisoned; the planner still serves.
        assert_eq!(planner.len(), 1);
        let t = planner.plan(128, Strategy::DualSelect, Direction::Forward).unwrap();
        assert_eq!(t.len(), 128);
        assert_eq!(planner.len(), 2);
    }
}
