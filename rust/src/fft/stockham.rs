//! Radix-2 Stockham autosort FFT — the paper's transform structure
//! (§IV-B: "a Stockham FFT with m = log2 N passes").
//!
//! Out-of-place ping-pong between the data buffer and a scratch buffer;
//! no bit-reversal permutation (the autosort property).  Pass `p` views
//! the half-arrays as `(l, s)` blocks (`s = 2^p`, `l = n/2^{p+1}`),
//! applies the butterfly with twiddle `W^{j·l}` along the stride axis,
//! and interleaves the outputs as `(l, 2, s)`.

use crate::precision::{Real, SplitBuf};

use super::plan::{PassTable, Plan};
use super::Direction;

/// Execute one pass from its precomputed table.
///
/// `x*` are the input halves (length n), `y*` the output (length n).
pub fn run_pass<T: Real>(
    table: &PassTable<T>,
    xre: &[T],
    xim: &[T],
    yre: &mut [T],
    yim: &mut [T],
) {
    let n = xre.len();
    let s = table.s;
    let l = n / (2 * s);
    debug_assert_eq!(n % (2 * s), 0);

    let (are, bre) = xre.split_at(n / 2);
    let (aim, bim) = xim.split_at(n / 2);

    match &table.kind {
        super::plan::PassKind::Plain(tab) => {
            for k in 0..l {
                let base_in = k * s;
                let base_out = 2 * k * s;
                for j in 0..s {
                    let (a_r, a_i, b_r, b_i) = super::butterfly::standard(
                        are[base_in + j],
                        aim[base_in + j],
                        bre[base_in + j],
                        bim[base_in + j],
                        tab.wr[j],
                        tab.wi[j],
                    );
                    yre[base_out + j] = a_r;
                    yim[base_out + j] = a_i;
                    yre[base_out + s + j] = b_r;
                    yim[base_out + s + j] = b_i;
                }
            }
        }
        super::plan::PassKind::Ratio(tab) => {
            // §Perf iteration 2/3: (a) tables that are exactly W^0
            // (dual-select / standard pass 0) degenerate to add/sub;
            // (b) otherwise iterate constant-`sel` runs so the path
            // choice is hoisted out of the inner loop and the body
            // vectorizes.  Both preserve rounding semantics exactly.
            if table.trivial {
                for k in 0..l {
                    let i = k * s;
                    let o = 2 * k * s;
                    for j in 0..s {
                        let (ar, ai, br, bi) =
                            (are[i + j], aim[i + j], bre[i + j], bim[i + j]);
                        yre[o + j] = ar + br;
                        yim[o + j] = ai + bi;
                        yre[o + s + j] = ar - br;
                        yim[o + s + j] = ai - bi;
                    }
                }
            } else {
                for k in 0..l {
                    let base_in = k * s;
                    let base_out = 2 * k * s;
                    // Slice windows give LLVM exact loop bounds (no
                    // per-element bounds checks in the 6-FMA body).
                    let ar = &are[base_in..base_in + s];
                    let ai = &aim[base_in..base_in + s];
                    let br = &bre[base_in..base_in + s];
                    let bi = &bim[base_in..base_in + s];
                    let (yar, ybr) = yre[base_out..base_out + 2 * s].split_at_mut(s);
                    let (yai, ybi) = yim[base_out..base_out + 2 * s].split_at_mut(s);
                    // NOTE (§Perf L3): per-element select beats
                    // constant-`sel` segment dispatch here — both
                    // segment variants measured slower (EXPERIMENTS.md
                    // iterations 2 and 5); the cmov pipeline wins.
                    for j in 0..s {
                        let (a_r, a_i, b_r, b_i) = super::butterfly::ratio(
                            ar[j], ai[j], br[j], bi[j],
                            tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j],
                        );
                        yar[j] = a_r;
                        yai[j] = a_i;
                        ybr[j] = b_r;
                        ybi[j] = b_i;
                    }
                }
            }
        }
    }
}

/// Full transform over borrowed planar slices — the zero-copy core
/// that [`execute`] and the batch path (`Transform::execute_many`)
/// both drive.  Ping-pongs between the frame (`re`/`im`) and the
/// caller's scratch planes, leaving the result in the frame; applies
/// the 1/n scale for inverse plans.
///
/// When the pass count is odd the input is first copied (exactly) into
/// scratch so the ping-pong still terminates in the frame — frames
/// borrowed from an arena cannot be pointer-swapped the way owned
/// buffers were.
pub fn execute_in<T: Real>(
    plan: &Plan<T>,
    re: &mut [T],
    im: &mut [T],
    sre: &mut [T],
    sim: &mut [T],
) {
    let n = plan.n;
    assert_eq!(re.len(), n, "buffer length != plan size");
    assert_eq!(im.len(), n, "buffer length != plan size");
    assert_eq!(sre.len(), n, "scratch length != plan size");
    assert_eq!(sim.len(), n, "scratch length != plan size");

    // `src_in_frame` tracks where the current pass reads from.  With
    // an odd pass count, start from scratch so pass parity lands the
    // final write in the frame.
    let mut src_in_frame = plan.passes.len() % 2 == 0;
    if !src_in_frame {
        sre.copy_from_slice(re);
        sim.copy_from_slice(im);
    }
    for table in &plan.passes {
        if src_in_frame {
            run_pass(table, re, im, sre, sim);
        } else {
            run_pass(table, sre, sim, re, im);
        }
        src_in_frame = !src_in_frame;
    }
    debug_assert!(src_in_frame, "result must end in the frame");

    if plan.direction == Direction::Inverse {
        let inv_n = T::from_f64(1.0 / n as f64);
        for x in re.iter_mut() {
            *x = *x * inv_n;
        }
        for x in im.iter_mut() {
            *x = *x * inv_n;
        }
    }
}

/// Full transform: executes every pass of `plan`, ping-ponging with
/// `scratch`, leaving the result in `buf`.  Applies the 1/n scale for
/// inverse plans.  (Owned-buffer adapter over [`execute_in`].)
pub fn execute<T: Real>(plan: &Plan<T>, buf: &mut SplitBuf<T>, scratch: &mut SplitBuf<T>) {
    let n = plan.n;
    assert_eq!(buf.len(), n, "buffer length != plan size");
    if scratch.len() != n {
        *scratch = SplitBuf::zeroed(n);
    }
    execute_in(plan, &mut buf.re, &mut buf.im, &mut scratch.re, &mut scratch.im);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft;
    use crate::fft::{Direction, Plan, Strategy};
    use crate::precision::{Bf16, F16};
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    fn random_signal(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Pcg32::seed(seed);
        (
            (0..n).map(|_| rng.gaussian()).collect(),
            (0..n).map(|_| rng.gaussian()).collect(),
        )
    }

    fn run<T: crate::precision::Real>(
        n: usize,
        strategy: Strategy,
        dir: Direction,
        re: &[f64],
        im: &[f64],
    ) -> (Vec<f64>, Vec<f64>) {
        let plan = Plan::<T>::new(n, strategy, dir).unwrap();
        let mut buf = SplitBuf::<T>::from_f64(re, im);
        let mut scratch = SplitBuf::zeroed(n);
        execute(&plan, &mut buf, &mut scratch);
        buf.to_f64()
    }

    #[test]
    fn all_strategies_match_dft_oracle_f64() {
        for n in [2usize, 4, 8, 32, 128, 1024] {
            let (re, im) = random_signal(n, n as u64);
            let (wr, wi) = dft::naive_dft(&re, &im, false);
            for strategy in Strategy::ALL {
                let (gr, gi) = run::<f64>(n, strategy, Direction::Forward, &re, &im);
                let err = rel_l2(&gr, &gi, &wr, &wi);
                let tol = match strategy {
                    Strategy::LinzerFeig | Strategy::Cosine => 5e-6, // clamp damage
                    _ => 1e-12,
                };
                assert!(err < tol, "n={n} {strategy:?} err={err:.3e}");
            }
        }
    }

    #[test]
    fn f32_roundtrip_error_matches_paper() {
        // Paper §V "FP32 precision": ~1e-7 relative L2 roundtrip for
        // both LF and dual-select.
        let n = 1024;
        let (re, im) = random_signal(n, 42);
        for strategy in [Strategy::LinzerFeig, Strategy::DualSelect] {
            let (fr, fi) = run::<f32>(n, strategy, Direction::Forward, &re, &im);
            let (gr, gi) = run::<f32>(n, strategy, Direction::Inverse, &fr, &fi);
            let err = rel_l2(&gr, &gi, &re, &im);
            assert!(err < 1e-6, "{strategy:?} roundtrip {err:.3e}");
        }
    }

    #[test]
    fn fp16_dual_select_works_where_lf_fails() {
        // The paper's headline: in half precision LF's clamped table
        // (ratio 1e7 -> inf in fp16) destroys the transform; dual-select
        // stays at O(m·eps).
        let n = 1024;
        let (re, im) = random_signal(n, 7);
        let (wr, wi) = dft::naive_dft(&re, &im, false);

        let (dr, di) = run::<F16>(n, Strategy::DualSelect, Direction::Forward, &re, &im);
        let err_dual = rel_l2(&dr, &di, &wr, &wi);
        assert!(err_dual < 0.05, "dual fp16 err {err_dual:.3e}");

        let (lr, li) = run::<F16>(n, Strategy::LinzerFeig, Direction::Forward, &re, &im);
        let err_lf = rel_l2(&lr, &li, &wr, &wi);
        assert!(
            err_lf.is_nan() || err_lf > 10.0 * err_dual,
            "lf fp16 err {err_lf:.3e} vs dual {err_dual:.3e}"
        );
    }

    #[test]
    fn bf16_dual_select_beats_lf() {
        // bf16 has f32's exponent range, so the clamped LF entries stay
        // finite — but still amplify error by orders of magnitude.
        let n = 256;
        let (re, im) = random_signal(n, 8);
        let (wr, wi) = dft::naive_dft(&re, &im, false);
        let (dr, di) = run::<Bf16>(n, Strategy::DualSelect, Direction::Forward, &re, &im);
        let (lr, li) = run::<Bf16>(n, Strategy::LinzerFeig, Direction::Forward, &re, &im);
        let err_dual = rel_l2(&dr, &di, &wr, &wi);
        let err_lf = rel_l2(&lr, &li, &wr, &wi);
        assert!(err_dual < 0.2, "dual bf16 {err_dual:.3e}");
        assert!(err_lf > err_dual, "lf {err_lf:.3e} dual {err_dual:.3e}");
    }

    #[test]
    fn impulse_transforms_to_flat_spectrum() {
        let n = 64;
        let mut re = vec![0.0; n];
        re[0] = 1.0;
        let im = vec![0.0; n];
        let (gr, gi) = run::<f64>(n, Strategy::DualSelect, Direction::Forward, &re, &im);
        for k in 0..n {
            assert!((gr[k] - 1.0).abs() < 1e-12);
            assert!(gi[k].abs() < 1e-12);
        }
    }

    #[test]
    fn single_tone_lands_in_one_bin() {
        let n = 128;
        let f = 9;
        let re: Vec<f64> = (0..n)
            .map(|t| (2.0 * core::f64::consts::PI * (f * t) as f64 / n as f64).cos())
            .collect();
        let im = vec![0.0; n];
        let (gr, gi) = run::<f64>(n, Strategy::DualSelect, Direction::Forward, &re, &im);
        for k in 0..n {
            let mag = (gr[k] * gr[k] + gi[k] * gi[k]).sqrt();
            if k == f || k == n - f {
                assert!((mag - n as f64 / 2.0).abs() < 1e-9, "bin {k} mag {mag}");
            } else {
                assert!(mag < 1e-9, "bin {k} mag {mag}");
            }
        }
    }

    #[test]
    fn linearity_and_parseval() {
        let n = 256;
        let (ar, ai) = random_signal(n, 100);
        let (br, bi) = random_signal(n, 101);
        let sum_r: Vec<f64> = ar.iter().zip(&br).map(|(x, y)| x + y).collect();
        let sum_i: Vec<f64> = ai.iter().zip(&bi).map(|(x, y)| x + y).collect();
        let (fa_r, fa_i) = run::<f64>(n, Strategy::DualSelect, Direction::Forward, &ar, &ai);
        let (fb_r, fb_i) = run::<f64>(n, Strategy::DualSelect, Direction::Forward, &br, &bi);
        let (fs_r, fs_i) = run::<f64>(n, Strategy::DualSelect, Direction::Forward, &sum_r, &sum_i);
        let want_r: Vec<f64> = fa_r.iter().zip(&fb_r).map(|(x, y)| x + y).collect();
        let want_i: Vec<f64> = fa_i.iter().zip(&fb_i).map(|(x, y)| x + y).collect();
        assert!(rel_l2(&fs_r, &fs_i, &want_r, &want_i) < 1e-12);

        // Parseval: sum |x|^2 == sum |X|^2 / n
        let te: f64 = ar.iter().zip(&ai).map(|(r, i)| r * r + i * i).sum();
        let fe: f64 = fa_r.iter().zip(&fa_i).map(|(r, i)| r * r + i * i).sum::<f64>() / n as f64;
        assert!((te - fe).abs() / te < 1e-12);
    }

    #[test]
    fn inverse_undoes_forward_exactly_in_f64() {
        let n = 512;
        let (re, im) = random_signal(n, 55);
        let (fr, fi) = run::<f64>(n, Strategy::DualSelect, Direction::Forward, &re, &im);
        let (gr, gi) = run::<f64>(n, Strategy::DualSelect, Direction::Inverse, &fr, &fi);
        assert!(rel_l2(&gr, &gi, &re, &im) < 1e-12);
    }

    #[test]
    fn time_shift_is_phase_ramp() {
        let n = 64;
        let (re, im) = random_signal(n, 77);
        let shift = 5usize;
        let sr: Vec<f64> = (0..n).map(|i| re[(i + n - shift) % n]).collect();
        let si: Vec<f64> = (0..n).map(|i| im[(i + n - shift) % n]).collect();
        let (fr, fi) = run::<f64>(n, Strategy::DualSelect, Direction::Forward, &re, &im);
        let (gr, gi) = run::<f64>(n, Strategy::DualSelect, Direction::Forward, &sr, &si);
        for k in 0..n {
            let phi = -2.0 * core::f64::consts::PI * (k * shift) as f64 / n as f64;
            let (c, s) = (phi.cos(), phi.sin());
            let wr = fr[k] * c - fi[k] * s;
            let wi = fr[k] * s + fi[k] * c;
            assert!((gr[k] - wr).abs() < 1e-10, "k={k}");
            assert!((gi[k] - wi).abs() < 1e-10, "k={k}");
        }
    }
}
