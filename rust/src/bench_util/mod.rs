//! Criterion-lite: a small benchmarking harness (criterion is not
//! available offline).  Warmup + timed samples + robust statistics,
//! with ns/op and throughput reporting.

use std::time::{Duration, Instant};

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum number of timed samples.
    pub min_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
        }
    }
}

impl BenchConfig {
    /// A faster profile for CI-style runs.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_samples: 5,
        }
    }
}

/// Result statistics (per iteration, nanoseconds).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    /// Mean iterations per second.
    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }

    /// Throughput in "units/s" given units processed per iteration.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        self.per_second() * units_per_iter
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.0} ns/iter  (median {:.0}, p99 {:.0}, sd {:.0}, n={})",
            self.name, self.mean_ns, self.median_ns, self.p99_ns, self.stddev_ns, self.samples
        )
    }
}

/// Run `f` repeatedly: warm up, then time batches until `measure`
/// elapses.  `f` should perform ONE logical iteration and return a
/// value (use `std::hint::black_box` inside as needed).
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup & per-iteration estimate.
    let w0 = Instant::now();
    let mut warm_iters = 0u64;
    while w0.elapsed() < cfg.warmup || warm_iters < 3 {
        f();
        warm_iters += 1;
    }
    let est = w0.elapsed().as_nanos() as f64 / warm_iters as f64;

    // Choose a batch size so each sample is ~1% of the measure budget
    // (amortizes timer overhead for nanosecond-scale bodies).
    let target_sample_ns = (cfg.measure.as_nanos() as f64 / 100.0).max(1000.0);
    let batch = ((target_sample_ns / est.max(1.0)).ceil() as u64).clamp(1, 1 << 24);

    let mut samples_ns: Vec<f64> = Vec::new();
    let m0 = Instant::now();
    while m0.elapsed() < cfg.measure || samples_ns.len() < cfg.min_samples {
        let s0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples_ns.push(s0.elapsed().as_nanos() as f64 / batch as f64);
        if samples_ns.len() > 100_000 {
            break;
        }
    }

    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        samples: n,
        mean_ns: mean,
        median_ns: samples_ns[n / 2],
        p99_ns: samples_ns[(n * 99 / 100).min(n - 1)],
        stddev_ns: var.sqrt(),
    }
}

/// Pretty header for a bench binary.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Quick-mode toggle from the environment (`FMAFFT_BENCH_QUICK=1`).
pub fn config_from_env() -> BenchConfig {
    if std::env::var("FMAFFT_BENCH_QUICK").ok().as_deref() == Some("1") {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_a_known_body() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(50),
            min_samples: 5,
        };
        let mut x = 0u64;
        let r = bench("spin", &cfg, || {
            for i in 0..1000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
        });
        assert!(r.samples >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p99_ns * 1.001);
        assert!(r.per_second() > 0.0);
    }

    #[test]
    fn throughput_scales_with_units() {
        let r = BenchResult {
            name: "x".into(),
            samples: 1,
            mean_ns: 1000.0,
            median_ns: 1000.0,
            p99_ns: 1000.0,
            stddev_ns: 0.0,
        };
        assert_eq!(r.per_second(), 1e6);
        assert_eq!(r.throughput(1024.0), 1024.0 * 1e6);
    }
}
