//! Criterion-lite: a small benchmarking harness (criterion is not
//! available offline).  Warmup + timed samples + robust statistics,
//! with ns/op and throughput reporting — plus machine-readable output
//! ([`BenchResult::to_json`], [`JsonReport`]) so the perf trajectory
//! is tracked across PRs as `BENCH_<suite>.json` files.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum number of timed samples.
    pub min_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
        }
    }
}

impl BenchConfig {
    /// A faster profile for CI-style runs.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_samples: 5,
        }
    }
}

/// Result statistics (per iteration, nanoseconds), plus the element
/// dtype and butterfly strategy of the measured workload so the
/// cross-PR perf trajectory is comparable per precision.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub stddev_ns: f64,
    /// Element dtype of the workload ("f32", "f16", ...), when known.
    pub dtype: Option<String>,
    /// Butterfly strategy of the workload ("dual", "lf", ...), when
    /// applicable.
    pub strategy: Option<String>,
}

impl BenchResult {
    /// Tag this result with the workload's element dtype and strategy
    /// (recorded in the JSON report).
    pub fn tagged(mut self, dtype: &str, strategy: &str) -> Self {
        self.dtype = Some(dtype.to_string());
        self.strategy = Some(strategy.to_string());
        self
    }

    /// Mean iterations per second.
    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }

    /// Throughput in "units/s" given units processed per iteration.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        self.per_second() * units_per_iter
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.0} ns/iter  (median {:.0}, p99 {:.0}, sd {:.0}, n={})",
            self.name, self.mean_ns, self.median_ns, self.p99_ns, self.stddev_ns, self.samples
        )
    }

    /// One JSON object with every statistic (machine-readable form of
    /// [`BenchResult::report`]); includes `dtype`/`strategy` when the
    /// result was [`BenchResult::tagged`].
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"name\":{},\"samples\":{},\"mean_ns\":{},\"median_ns\":{},\"p99_ns\":{},\"stddev_ns\":{},\"per_second\":{}",
            json_escape(&self.name),
            self.samples,
            json_num(self.mean_ns),
            json_num(self.median_ns),
            json_num(self.p99_ns),
            json_num(self.stddev_ns),
            json_num(self.per_second()),
        );
        if let Some(dtype) = &self.dtype {
            out.push_str(&format!(",\"dtype\":{}", json_escape(dtype)));
        }
        if let Some(strategy) = &self.strategy {
            out.push_str(&format!(",\"strategy\":{}", json_escape(strategy)));
        }
        out.push('}');
        out
    }
}

/// Quote + escape a string for JSON.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format an f64 as a JSON number (JSON has no NaN/Inf — map to null).
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Collects bench results and free-form metric rows, then writes one
/// `BENCH_<suite>.json` file — the cross-PR perf trajectory record.
///
/// ```text
/// {"suite":"fft","results":[
///   {"name":"stockham r2 dual n=1024","mean_ns":...},
///   {"name":"serving rate=5000","completed":..., "p99_us":...}
/// ]}
/// ```
#[derive(Clone, Debug)]
pub struct JsonReport {
    suite: String,
    entries: Vec<String>,
}

impl JsonReport {
    pub fn new(suite: &str) -> Self {
        JsonReport { suite: suite.to_string(), entries: Vec::new() }
    }

    /// Append a harness result.
    pub fn push_result(&mut self, r: &BenchResult) {
        self.entries.push(r.to_json());
    }

    /// Append a named row of scalar metrics (for benches that measure
    /// things other than ns/iter, e.g. serving latency quantiles).
    pub fn push_metrics(&mut self, name: &str, fields: &[(&str, f64)]) {
        self.push_entry(name, &[], fields);
    }

    /// [`JsonReport::push_metrics`] with the workload's element dtype
    /// and strategy recorded alongside the numbers.
    pub fn push_metrics_tagged(
        &mut self,
        name: &str,
        dtype: &str,
        strategy: &str,
        fields: &[(&str, f64)],
    ) {
        self.push_entry(name, &[("dtype", dtype), ("strategy", strategy)], fields);
    }

    /// [`JsonReport::push_metrics`] with arbitrary string tags (e.g.
    /// `("transport", "tcp")`) recorded alongside the numbers — the
    /// general form behind [`JsonReport::push_metrics_tagged`].
    pub fn push_metrics_tags(
        &mut self,
        name: &str,
        tags: &[(&str, &str)],
        fields: &[(&str, f64)],
    ) {
        self.push_entry(name, tags, fields);
    }

    fn push_entry(&mut self, name: &str, tags: &[(&str, &str)], fields: &[(&str, f64)]) {
        let mut obj = format!("{{\"name\":{}", json_escape(name));
        for (k, v) in tags {
            obj.push_str(&format!(",{}:{}", json_escape(k), json_escape(v)));
        }
        for (k, v) in fields {
            obj.push_str(&format!(",{}:{}", json_escape(k), json_num(*v)));
        }
        obj.push('}');
        self.entries.push(obj);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The complete document.
    pub fn render(&self) -> String {
        format!(
            "{{\"suite\":{},\"results\":[{}]}}\n",
            json_escape(&self.suite),
            self.entries.join(",")
        )
    }

    /// Write `BENCH_<suite>.json` into `dir`; returns the path.
    pub fn write(&self, dir: impl AsRef<Path>) -> std::io::Result<PathBuf> {
        let path = dir.as_ref().join(format!("BENCH_{}.json", self.suite));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.render().as_bytes())?;
        Ok(path)
    }
}

/// Run `f` repeatedly: warm up, then time batches until `measure`
/// elapses.  `f` should perform ONE logical iteration and return a
/// value (use `std::hint::black_box` inside as needed).
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup & per-iteration estimate.
    let w0 = Instant::now();
    let mut warm_iters = 0u64;
    while w0.elapsed() < cfg.warmup || warm_iters < 3 {
        f();
        warm_iters += 1;
    }
    let est = w0.elapsed().as_nanos() as f64 / warm_iters as f64;

    // Choose a batch size so each sample is ~1% of the measure budget
    // (amortizes timer overhead for nanosecond-scale bodies).
    let target_sample_ns = (cfg.measure.as_nanos() as f64 / 100.0).max(1000.0);
    let batch = ((target_sample_ns / est.max(1.0)).ceil() as u64).clamp(1, 1 << 24);

    let mut samples_ns: Vec<f64> = Vec::new();
    let m0 = Instant::now();
    while m0.elapsed() < cfg.measure || samples_ns.len() < cfg.min_samples {
        let s0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples_ns.push(s0.elapsed().as_nanos() as f64 / batch as f64);
        if samples_ns.len() > 100_000 {
            break;
        }
    }

    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        samples: n,
        mean_ns: mean,
        median_ns: samples_ns[n / 2],
        p99_ns: samples_ns[(n * 99 / 100).min(n - 1)],
        stddev_ns: var.sqrt(),
        dtype: None,
        strategy: None,
    }
}

/// Pretty header for a bench binary.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Quick-mode toggle from the environment (`FMAFFT_BENCH_QUICK=1`).
pub fn config_from_env() -> BenchConfig {
    if std::env::var("FMAFFT_BENCH_QUICK").ok().as_deref() == Some("1") {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_a_known_body() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(50),
            min_samples: 5,
        };
        let mut x = 0u64;
        let r = bench("spin", &cfg, || {
            for i in 0..1000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
        });
        assert!(r.samples >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p99_ns * 1.001);
        assert!(r.per_second() > 0.0);
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let r = BenchResult {
            name: "stockham \"r2\" n=1024".into(),
            samples: 12,
            mean_ns: 1500.5,
            median_ns: 1400.0,
            p99_ns: 2000.0,
            stddev_ns: 100.25,
            dtype: None,
            strategy: None,
        };
        let v = crate::util::json::Json::parse(&r.to_json()).expect("valid json");
        assert_eq!(v.get("name").unwrap().as_str(), Some("stockham \"r2\" n=1024"));
        assert_eq!(v.get("samples").unwrap().as_usize(), Some(12));
        assert_eq!(v.get("mean_ns").unwrap().as_f64(), Some(1500.5));
        // Untagged results carry no dtype/strategy keys.
        assert_eq!(v.get("dtype"), None);

        let mut rep = JsonReport::new("fft");
        rep.push_result(&r);
        rep.push_metrics("serving rate=5000", &[("p99_us", 750.0), ("occupancy", 0.82)]);
        assert_eq!(rep.len(), 2);
        let doc = crate::util::json::Json::parse(rep.render().trim()).expect("valid doc");
        assert_eq!(doc.get("suite").unwrap().as_str(), Some("fft"));
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[1].get("occupancy").unwrap().as_f64(), Some(0.82));
    }

    #[test]
    fn json_entries_record_dtype_and_strategy() {
        let r = BenchResult {
            name: "stockham r2 n=1024".into(),
            samples: 3,
            mean_ns: 100.0,
            median_ns: 100.0,
            p99_ns: 100.0,
            stddev_ns: 0.0,
            dtype: None,
            strategy: None,
        }
        .tagged("f16", "dual");
        let v = crate::util::json::Json::parse(&r.to_json()).expect("valid json");
        assert_eq!(v.get("dtype").unwrap().as_str(), Some("f16"));
        assert_eq!(v.get("strategy").unwrap().as_str(), Some("dual"));
        assert_eq!(v.get("mean_ns").unwrap().as_f64(), Some(100.0));

        let mut rep = JsonReport::new("serving");
        rep.push_metrics_tagged("native rate=2000", "bf16", "dual", &[("p99_us", 420.0)]);
        let doc = crate::util::json::Json::parse(rep.render().trim()).expect("valid doc");
        let row = &doc.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("dtype").unwrap().as_str(), Some("bf16"));
        assert_eq!(row.get("strategy").unwrap().as_str(), Some("dual"));
        assert_eq!(row.get("p99_us").unwrap().as_f64(), Some(420.0));
    }

    #[test]
    fn json_entries_record_arbitrary_string_tags() {
        let mut rep = JsonReport::new("serving");
        rep.push_metrics_tags(
            "tcp clients=4",
            &[("dtype", "f32"), ("strategy", "dual"), ("transport", "tcp")],
            &[("completed", 500.0)],
        );
        let doc = crate::util::json::Json::parse(rep.render().trim()).expect("valid doc");
        let row = &doc.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("transport").unwrap().as_str(), Some("tcp"));
        assert_eq!(row.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(row.get("completed").unwrap().as_f64(), Some(500.0));
    }

    #[test]
    fn json_report_writes_bench_file() {
        let dir = std::env::temp_dir().join("fmafft_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rep = JsonReport::new("testsuite");
        rep.push_metrics("row", &[("x", 1.0), ("bad", f64::NAN)]);
        let path = rep.write(&dir).unwrap();
        assert!(path.ends_with("BENCH_testsuite.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::Json::parse(text.trim()).unwrap();
        let row = &doc.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(row.get("bad"), Some(&crate::util::json::Json::Null));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn throughput_scales_with_units() {
        let r = BenchResult {
            name: "x".into(),
            samples: 1,
            mean_ns: 1000.0,
            median_ns: 1000.0,
            p99_ns: 1000.0,
            stddev_ns: 0.0,
            dtype: None,
            strategy: None,
        };
        assert_eq!(r.per_second(), 1e6);
        assert_eq!(r.throughput(1024.0), 1024.0 * 1e6);
    }
}
