//! Per-pass twiddle tables for the mixed-radix engine.
//!
//! A radix-`r` pass at stride `s` multiplies its `q`-th input
//! (`q ∈ 1..r`) by `W_n^{q·j·l}` (`l = n/(r·s)`, `j ∈ 0..s`) before
//! the internal `r`-point DFT.  Every one of those multiplies is
//! stored in the paper's bounded-ratio form — the same
//! `(m1, m2, t, sel)` dual-select layout [`crate::fft::twiddle`]
//! builds for the radix-2 plan — so vectorizing the kernel changes
//! nothing about the numerical contract: `|t| ≤ 1` per entry for
//! dual-select, per twiddle power, at every radix (paper §VI).
//!
//! Layout: one [`RatioTable`] per twiddle power `q`, each `s` entries
//! long, held contiguously per pass (`tables[q-1]`) — the interleaved
//! per-pass layout of the Autosort exemplars, transposed to planes so
//! the SIMD inner loops load `m1/m2/t` with unit stride.  The `sel`
//! lane is additionally materialized as a 0.0/1.0 mask plane
//! (`selm`), which is what the AVX2 arm blends on; the scalar arm
//! reads the `bool` lane.  Both arms see the same table values, which
//! is half of the bit-identity argument (the other half is the
//! op-for-op FMA correspondence in [`super::butterflies`]).

use crate::fft::twiddle::{ratio_table, RatioTable};
use crate::fft::{Direction, Strategy};
use crate::precision::Real;

use super::schedule::plan_radices;

/// Twiddle tables for one mixed-radix pass.
#[derive(Clone, Debug)]
pub struct PassTables<T> {
    /// Butterfly radix of this pass (2, 3, 4 or 8).
    pub radix: usize,
    /// Twiddle stride: the product of all earlier passes' radices.
    pub s: usize,
    /// `tables[q-1]` holds the ratio entries for `W_n^{q·j·l}`.
    pub tables: Vec<RatioTable<T>>,
    /// `sel` as a 0.0 (sine path) / 1.0 (cosine path) mask plane per
    /// twiddle power — the branch-free blend form the SIMD arm uses.
    pub selm: Vec<Vec<T>>,
    /// True when every table is the exact trivial twiddle `W^0`: the
    /// pass degenerates to the pure `r`-point DFT.  (Exactly the
    /// radix-2 plan's trivial-pass rule; for dual-select this is the
    /// `s = 1` pass, while the clamped baselines' huge `W^0` entries
    /// keep the general path — that difference *is* the paper.)
    pub trivial: bool,
}

impl<T: Real> PassTables<T> {
    /// Build the tables for one pass of an `n`-point transform.
    pub fn build(n: usize, radix: usize, s: usize, direction: Direction, strategy: Strategy) -> Self {
        let l = n / (radix * s);
        debug_assert_eq!(n % (radix * s), 0);
        let sign = direction.sign();
        let mut tables = Vec::with_capacity(radix - 1);
        let mut selm = Vec::with_capacity(radix - 1);
        for q in 1..radix {
            let angles: Vec<f64> = (0..s)
                .map(|j| sign * 2.0 * core::f64::consts::PI * (q * j * l) as f64 / n as f64)
                .collect();
            let tab = ratio_table::<T>(&angles, strategy);
            selm.push(
                tab.sel
                    .iter()
                    .map(|&c| if c { T::one() } else { T::zero() })
                    .collect(),
            );
            tables.push(tab);
        }
        let trivial = tables.iter().all(|t| t.is_trivial());
        PassTables { radix, s, tables, selm, trivial }
    }

    /// Bytes held by this pass's tables (capacity reporting).
    pub fn table_bytes(&self) -> usize {
        let per_entry = 4 * core::mem::size_of::<T>() + core::mem::size_of::<bool>();
        (self.radix - 1) * self.s * per_entry
    }
}

/// Build the tables for every pass of a schedule.  `radices` must
/// multiply to `n` (validated by the plan constructor).
pub fn build_passes<T: Real>(
    n: usize,
    radices: &[usize],
    direction: Direction,
    strategy: Strategy,
) -> Vec<PassTables<T>> {
    let mut out = Vec::with_capacity(radices.len());
    let mut s = 1usize;
    for &r in radices {
        out.push(PassTables::build(n, r, s, direction, strategy));
        s *= r;
    }
    out
}

/// Max |ratio| across every twiddle table of the canonical schedule
/// for `n`, as *stored* in f64 (clamped entries included — for the
/// clamped baselines that is the honest, ugly number).  `None` when
/// the mixed-radix plan does not serve `(n, strategy)` — the bound
/// attachment then has nothing to price.
pub fn tables_tmax(n: usize, strategy: Strategy) -> Option<f64> {
    if strategy == Strategy::Standard {
        return None;
    }
    let radices = plan_radices(n).ok()?;
    let passes = build_passes::<f64>(n, &radices, Direction::Forward, strategy);
    let mut worst = 0.0f64;
    for pass in &passes {
        for tab in &pass.tables {
            for &t in &tab.t {
                worst = worst.max(t.abs());
            }
        }
    }
    Some(worst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_select_ratio_bound_holds_at_every_radix() {
        // Paper §VI: per-twiddle min-ratio selection keeps |t| ≤ 1
        // for every power q at every radix — vectorization changes
        // the kernel, never the table.
        for n in [6usize, 12, 24, 48, 96, 144, 768, 1536] {
            let tmax = tables_tmax(n, Strategy::DualSelect).unwrap();
            assert!(tmax <= 1.0 + 1e-15, "n={n} tmax={tmax}");
        }
    }

    #[test]
    fn clamped_baselines_stay_unbounded() {
        // The W^0 entry of the first pass is clamped for LF: the
        // mixed-radix table reports it honestly.
        let lf = tables_tmax(48, Strategy::LinzerFeig).unwrap();
        assert!(lf > 1e6, "lf tmax {lf}");
        assert_eq!(tables_tmax(48, Strategy::Standard), None);
        assert_eq!(tables_tmax(100, Strategy::DualSelect), None);
    }

    #[test]
    fn first_pass_is_trivial_for_dual_select_only() {
        let dual = PassTables::<f64>::build(24, 3, 1, Direction::Forward, Strategy::DualSelect);
        assert!(dual.trivial);
        let lf = PassTables::<f64>::build(24, 3, 1, Direction::Forward, Strategy::LinzerFeig);
        assert!(!lf.trivial, "clamped W^0 must keep the general path");
    }

    #[test]
    fn selm_mirrors_sel_and_radix2_tables_match_the_plan() {
        use crate::fft::twiddle::pass_angles;
        let n = 64usize;
        // A radix-2 pass at s = 2^p must build the *same* table the
        // classic Stockham plan uses — the dual-select ratio table is
        // the kernel's numerical contract, unchanged.
        for p in 0..6u32 {
            let s = 1usize << p;
            let pass = PassTables::<f32>::build(n, 2, s, Direction::Forward, Strategy::DualSelect);
            let want = ratio_table::<f32>(
                &pass_angles(n, p, Direction::Forward),
                Strategy::DualSelect,
            );
            assert_eq!(pass.tables[0].m1, want.m1, "p={p}");
            assert_eq!(pass.tables[0].m2, want.m2, "p={p}");
            assert_eq!(pass.tables[0].t, want.t, "p={p}");
            assert_eq!(pass.tables[0].sel, want.sel, "p={p}");
            for (j, &c) in pass.tables[0].sel.iter().enumerate() {
                assert_eq!(pass.selm[0][j], if c { 1.0f32 } else { 0.0 });
            }
        }
    }

    #[test]
    fn build_passes_strides_multiply_through() {
        let passes = build_passes::<f64>(96, &[3, 8, 4], Direction::Inverse, Strategy::DualSelect);
        assert_eq!(passes.len(), 3);
        assert_eq!((passes[0].radix, passes[0].s), (3, 1));
        assert_eq!((passes[1].radix, passes[1].s), (8, 3));
        assert_eq!((passes[2].radix, passes[2].s), (4, 24));
        assert!(passes[0].table_bytes() < passes[2].table_bytes());
    }
}
