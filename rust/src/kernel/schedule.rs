//! Radix schedules: factor a {2,3}-smooth size into the per-pass
//! radices the mixed-radix engine executes.
//!
//! The canonical schedule puts the radix-3 passes first (they run
//! while the twiddle stride `s` is still small, where every radix is
//! equally scalar-bound) and then covers the power-of-two part with
//! the largest butterflies the remaining exponent admits — radix-8
//! greedily, radix-4 for the leftovers, radix-2 only when the
//! exponent is odd and too small for anything better.  Fewer, fatter
//! passes mean fewer sweeps over the frame, which is where the
//! vectorized kernels earn their multiplier.
//!
//! Any ordering of the same radices computes the same DFT (the
//! Stockham recurrence is order-free; `tests` below pin that), so the
//! schedule is purely a performance choice — `analysis::bounds` takes
//! the schedule, not the order, when it prices a plan.

use crate::fft::{FftError, FftResult};

/// The radices the engine has butterfly kernels for.
pub const SUPPORTED_RADICES: [usize; 4] = [2, 3, 4, 8];

/// Factor `n` as `2^a · 3^b`, or `None` when another prime divides it.
pub fn factor23(n: usize) -> Option<(u32, u32)> {
    if n == 0 {
        return None;
    }
    let mut m = n;
    let mut a = 0u32;
    let mut b = 0u32;
    while m % 2 == 0 {
        m /= 2;
        a += 1;
    }
    while m % 3 == 0 {
        m /= 3;
        b += 1;
    }
    (m == 1).then_some((a, b))
}

/// True when `n ≥ 2` has no prime factor other than 2 and 3 — the
/// sizes the mixed-radix plan serves.
pub fn is_23_smooth(n: usize) -> bool {
    n >= 2 && factor23(n).is_some()
}

/// The canonical pass schedule for a {2,3}-smooth `n ≥ 2`: radix-3
/// passes first, then the 2-exponent covered greedily by radix-8 with
/// radix-4/2 absorbing the remainder (an exponent of 4 splits as
/// 4·4 rather than 8·2 — two quad butterflies beat an 8 plus the
/// weakest pass).
pub fn plan_radices(n: usize) -> FftResult<Vec<usize>> {
    if n < 2 {
        return Err(FftError::InvalidSize {
            n,
            reason: "mixed-radix FFT size must be >= 2",
        });
    }
    let (mut a, b) = factor23(n).ok_or(FftError::InvalidSize {
        n,
        reason: "mixed-radix FFT size must factor as 2^a * 3^b",
    })?;
    let mut out = Vec::with_capacity((a + b) as usize);
    for _ in 0..b {
        out.push(3);
    }
    while a >= 3 {
        if a == 4 {
            out.extend([4, 4]);
            a = 0;
        } else {
            out.push(8);
            a -= 3;
        }
    }
    if a == 2 {
        out.push(4);
    } else if a == 1 {
        out.push(2);
    }
    Ok(out)
}

/// A pure radix-2 schedule for power-of-two `n` — the ablation
/// schedule whose pass structure (and therefore whose every rounding)
/// matches the classic radix-2 Stockham plan bit for bit.
pub fn radix2_radices(n: usize) -> FftResult<Vec<usize>> {
    let m = crate::fft::log2_exact(n)?;
    Ok(vec![2; m as usize])
}

/// Validate an explicit schedule against `n`: every radix supported,
/// product exactly `n`.
pub fn validate_radices(n: usize, radices: &[usize]) -> FftResult<()> {
    if radices.is_empty() {
        return Err(FftError::InvalidSize {
            n,
            reason: "mixed-radix schedule must have at least one pass",
        });
    }
    let mut prod = 1usize;
    for &r in radices {
        if !SUPPORTED_RADICES.contains(&r) {
            return Err(FftError::InvalidSize {
                n,
                reason: "mixed-radix schedule may only use radices 2, 3, 4, 8",
            });
        }
        prod = prod.checked_mul(r).ok_or(FftError::InvalidSize {
            n,
            reason: "mixed-radix schedule product overflows",
        })?;
    }
    if prod != n {
        return Err(FftError::InvalidSize {
            n,
            reason: "mixed-radix schedule product != n",
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor23_accepts_smooth_rejects_rest() {
        assert_eq!(factor23(1), Some((0, 0)));
        assert_eq!(factor23(48), Some((4, 1)));
        assert_eq!(factor23(1536), Some((9, 1)));
        assert_eq!(factor23(27), Some((0, 3)));
        assert_eq!(factor23(0), None);
        assert_eq!(factor23(100), None); // 2^2 · 5^2
        assert_eq!(factor23(7), None);
        assert!(is_23_smooth(96));
        assert!(!is_23_smooth(1)); // below the minimum transform size
        assert!(!is_23_smooth(60));
    }

    #[test]
    fn canonical_schedule_covers_the_exponents() {
        for n in [2usize, 4, 6, 8, 12, 16, 24, 27, 48, 96, 256, 768, 1024, 1536] {
            let radices = plan_radices(n).unwrap();
            validate_radices(n, &radices).unwrap();
            let (_, b) = factor23(n).unwrap();
            // Every 3 is at the front of the schedule.
            assert!(radices.iter().take(b as usize).all(|&r| r == 3), "n={n}");
        }
        // a=4 splits as 4·4, not 8·2.
        assert_eq!(plan_radices(16).unwrap(), vec![4, 4]);
        assert_eq!(plan_radices(48).unwrap(), vec![3, 4, 4]);
        // a=10 = 3+3+4.
        assert_eq!(plan_radices(1024).unwrap(), vec![8, 8, 4, 4]);
        // a=9 is all eights.
        assert_eq!(plan_radices(1536).unwrap(), vec![3, 8, 8, 8]);
        // Radix-2 appears only for odd exponents < 3.
        assert_eq!(plan_radices(2).unwrap(), vec![2]);
        assert_eq!(plan_radices(6).unwrap(), vec![3, 2]);
        assert!(plan_radices(96).unwrap().iter().all(|&r| r != 2));
    }

    #[test]
    fn schedule_rejects_non_smooth_and_tiny() {
        assert!(plan_radices(0).is_err());
        assert!(plan_radices(1).is_err());
        assert!(plan_radices(100).is_err());
        assert!(plan_radices(7).is_err());
    }

    #[test]
    fn radix2_schedule_matches_log2() {
        assert_eq!(radix2_radices(8).unwrap(), vec![2, 2, 2]);
        assert!(radix2_radices(12).is_err());
    }

    #[test]
    fn validate_catches_bad_schedules() {
        assert!(validate_radices(24, &[3, 8]).is_ok());
        assert!(validate_radices(24, &[8, 3]).is_ok());
        assert!(validate_radices(24, &[]).is_err());
        assert!(validate_radices(24, &[3, 4]).is_err()); // product 12
        assert!(validate_radices(24, &[24]).is_err()); // unsupported radix
    }
}
