//! Scalar radix-3/4/8 DFT micro-kernels — the formula sheet both
//! dispatch arms implement *op for op*.
//!
//! Bit-identity contract: the AVX2 arm in [`super::simd`] executes,
//! per lane, exactly the operation sequence written here — every
//! `mul_add(x, y, acc)` maps to one `vfmadd`, every
//! `mul_add(-x, y, acc)` to one `vfnmadd`, every `+`/`-`/`*` to the
//! corresponding vector op, and negation to a sign-bit flip.  Each of
//! those lane operations rounds identically to its scalar twin under
//! IEEE-754, and every output element depends only on its own gather
//! column, so the two arms produce the same bits regardless of loop
//! shape.  Change an expression here and you must change the SIMD arm
//! the same way (tests/kernel_plane.rs will catch you if you don't).
//!
//! The radix-2 butterfly is *not* redefined here: the mixed-radix
//! engine calls [`crate::fft::butterfly::ratio`] directly, so a
//! radix-2-only schedule reproduces the classic Stockham plan bit for
//! bit.

use crate::precision::Real;

/// √3/2 — the radix-3 rotation constant (nearest f64).
pub const SQRT3_2: f64 = 0.866_025_403_784_438_6;
/// 1/√2 — the radix-8 odd-term rotation constant.
pub const FRAC_1_SQRT_2: f64 = core::f64::consts::FRAC_1_SQRT_2;

/// 3-point DFT of already-twiddled inputs.  `fwd` selects the
/// e^{∓2πi/3} root to match [`crate::fft::Direction::sign`].
#[inline(always)]
pub fn dft3<T: Real>(z0: (T, T), z1: (T, T), z2: (T, T), fwd: bool) -> [(T, T); 3] {
    let half = T::from_f64(0.5);
    let c = T::from_f64(SQRT3_2);
    let sr = z1.0 + z2.0;
    let si = z1.1 + z2.1;
    let u0 = (z0.0 + sr, z0.1 + si);
    let mr = half.mul_add(-sr, z0.0); // z0 - s/2, one rounding
    let mi = half.mul_add(-si, z0.1);
    let dr = z1.0 - z2.0;
    let di = z1.1 - z2.1;
    // ∓i·(√3/2)·d folded into m: forward subtracts i·c·d, inverse adds.
    let (u1, u2) = if fwd {
        ((c.mul_add(di, mr), c.mul_add(-dr, mi)), (c.mul_add(-di, mr), c.mul_add(dr, mi)))
    } else {
        ((c.mul_add(-di, mr), c.mul_add(dr, mi)), (c.mul_add(di, mr), c.mul_add(-dr, mi)))
    };
    [u0, u1, u2]
}

/// 4-point DFT of already-twiddled inputs — the even/odd partial-sum
/// form of [`crate::fft::radix4`], kept verbatim so the mixed-radix
/// radix-4 pass rounds exactly like the dedicated radix-4 plan.
#[inline(always)]
pub fn dft4<T: Real>(z0: (T, T), z1: (T, T), z2: (T, T), z3: (T, T), fwd: bool) -> [(T, T); 4] {
    let e_r = z0.0 + z2.0;
    let e_i = z0.1 + z2.1;
    let f_r = z0.0 - z2.0;
    let f_i = z0.1 - z2.1;
    let g_r = z1.0 + z3.0;
    let g_i = z1.1 + z3.1;
    let h_r = z1.0 - z3.0;
    let h_i = z1.1 - z3.1;
    // ∓i·h: forward (h_i, -h_r), inverse (-h_i, h_r).
    let (jh_r, jh_i) = if fwd { (h_i, -h_r) } else { (-h_i, h_r) };
    [
        (e_r + g_r, e_i + g_i),
        (f_r + jh_r, f_i + jh_i),
        (e_r - g_r, e_i - g_i),
        (f_r - jh_r, f_i - jh_i),
    ]
}

/// 8-point DFT of already-twiddled inputs: two 4-point DFTs (even and
/// odd columns) glued by the ω_8^m rotations, whose only irrational
/// constant is 1/√2.
#[inline(always)]
pub fn dft8<T: Real>(z: [(T, T); 8], fwd: bool) -> [(T, T); 8] {
    let c = T::from_f64(FRAC_1_SQRT_2);
    let e = dft4(z[0], z[2], z[4], z[6], fwd);
    let o = dft4(z[1], z[3], z[5], z[7], fwd);
    // ω_8^m · o_m for m = 1..3 (m = 0 is the identity).
    let (r1, i1) = o[1];
    let (r2, i2) = o[2];
    let (r3, i3) = o[3];
    let (o1, o2, o3) = if fwd {
        (
            (c * (r1 + i1), c * (i1 - r1)),
            (i2, -r2),
            (c * (i3 - r3), -(c * (r3 + i3))),
        )
    } else {
        (
            (c * (r1 - i1), c * (i1 + r1)),
            (-i2, r2),
            (-(c * (r3 + i3)), c * (r3 - i3)),
        )
    };
    let rot = [o[0], o1, o2, o3];
    let mut out = [(T::zero(), T::zero()); 8];
    for m in 0..4 {
        out[m] = (e[m].0 + rot[m].0, e[m].1 + rot[m].1);
        out[m + 4] = (e[m].0 - rot[m].0, e[m].1 - rot[m].1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn oracle(z: &[(f64, f64)], sign: f64) -> Vec<(f64, f64)> {
        let r = z.len();
        (0..r)
            .map(|m| {
                let mut acc = (0.0, 0.0);
                for (q, &(re, im)) in z.iter().enumerate() {
                    let th = sign * 2.0 * core::f64::consts::PI * (q * m) as f64 / r as f64;
                    let (c, s) = (th.cos(), th.sin());
                    acc.0 += re * c - im * s;
                    acc.1 += re * s + im * c;
                }
                acc
            })
            .collect()
    }

    fn rand_z(rng: &mut Pcg32, r: usize) -> Vec<(f64, f64)> {
        (0..r).map(|_| (rng.gaussian(), rng.gaussian())).collect()
    }

    #[test]
    fn dft3_matches_oracle_both_directions() {
        let mut rng = Pcg32::seed(41);
        for _ in 0..200 {
            let z = rand_z(&mut rng, 3);
            for (fwd, sign) in [(true, -1.0), (false, 1.0)] {
                let got = dft3(z[0], z[1], z[2], fwd);
                for (g, w) in got.iter().zip(oracle(&z, sign)) {
                    assert!((g.0 - w.0).abs() < 1e-13 && (g.1 - w.1).abs() < 1e-13);
                }
            }
        }
    }

    #[test]
    fn dft4_matches_oracle_both_directions() {
        let mut rng = Pcg32::seed(42);
        for _ in 0..200 {
            let z = rand_z(&mut rng, 4);
            for (fwd, sign) in [(true, -1.0), (false, 1.0)] {
                let got = dft4(z[0], z[1], z[2], z[3], fwd);
                for (g, w) in got.iter().zip(oracle(&z, sign)) {
                    assert!((g.0 - w.0).abs() < 1e-13 && (g.1 - w.1).abs() < 1e-13);
                }
            }
        }
    }

    #[test]
    fn dft8_matches_oracle_both_directions() {
        let mut rng = Pcg32::seed(43);
        for _ in 0..200 {
            let z = rand_z(&mut rng, 8);
            for (fwd, sign) in [(true, -1.0), (false, 1.0)] {
                let arr: [(f64, f64); 8] = core::array::from_fn(|i| z[i]);
                let got = dft8(arr, fwd);
                for (g, w) in got.iter().zip(oracle(&z, sign)) {
                    assert!((g.0 - w.0).abs() < 1e-12 && (g.1 - w.1).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn kernels_hold_in_half_precision() {
        use crate::precision::F16;
        let mut rng = Pcg32::seed(44);
        let z: Vec<(F16, F16)> = (0..8)
            .map(|_| (F16::from_f64(rng.range(-1.0, 1.0)), F16::from_f64(rng.range(-1.0, 1.0))))
            .collect();
        let zf: Vec<(f64, f64)> = z.iter().map(|&(r, i)| (r.to_f64(), i.to_f64())).collect();
        let got = dft8(core::array::from_fn(|i| z[i]), true);
        for (g, w) in got.iter().zip(oracle(&zf, -1.0)) {
            assert!((g.0.to_f64() - w.0).abs() < 0.02, "{g:?} vs {w:?}");
            assert!((g.1.to_f64() - w.1).abs() < 0.02, "{g:?} vs {w:?}");
        }
    }
}
