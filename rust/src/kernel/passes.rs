//! Portable (scalar) mixed-radix Stockham passes.
//!
//! One pass of radix `r` at stride `s` views the state as `l = n/(r·s)`
//! blocks: element `q` of block `(k, j)` is gathered from
//! `x[k·s + j + q·(n/r)]`, twiddled by `W_n^{q·j·l}` (in bounded-ratio
//! form, [`super::twiddles`]), pushed through the `r`-point DFT
//! ([`super::butterflies`]), and scattered to `y[r·k·s + m·s + j]` —
//! the autosort interleave, so no bit-reversal ever happens.  For
//! radix 2 this is *exactly* the classic plan's pass
//! ([`crate::fft::stockham::run_pass`], same `ratio` kernel, same
//! trivial fast path), which is what makes a radix-2-only schedule bit
//! identical to [`crate::fft::Plan`].
//!
//! This module is the *portable dispatch arm*: plain indexed loops,
//! no intrinsics, valid on every target.  [`super::simd`] implements
//! the same passes with AVX2/FMA lanes and defers to the per-element
//! helpers here for loop remainders; both arms execute the same
//! per-element operation sequence, so their outputs are bit identical
//! (see tests/kernel_plane.rs).

use crate::fft::butterfly::{ratio, ratio_twiddle_mul};
use crate::precision::Real;

use super::butterflies::{dft3, dft4, dft8};
use super::twiddles::PassTables;

/// Execute one pass of `pass.radix` from `x` planes into `y` planes
/// (all length `n`) using the portable scalar loops.
pub fn run_pass<T: Real>(
    pass: &PassTables<T>,
    fwd: bool,
    xre: &[T],
    xim: &[T],
    yre: &mut [T],
    yim: &mut [T],
) {
    match pass.radix {
        2 => pass2(pass, xre, xim, yre, yim),
        3 => pass3(pass, fwd, xre, xim, yre, yim),
        4 => pass4(pass, fwd, xre, xim, yre, yim),
        8 => pass8(pass, fwd, xre, xim, yre, yim),
        r => unreachable!("unsupported radix {r} escaped schedule validation"),
    }
}

/// Radix-2 pass — the classic plan's pass body, verbatim: trivial
/// tables degenerate to add/sub, everything else runs the 6-FMA
/// `ratio` butterfly over slice windows.  (Direction lives entirely in
/// the table for radix 2, hence no `fwd` argument.)
fn pass2<T: Real>(pass: &PassTables<T>, xre: &[T], xim: &[T], yre: &mut [T], yim: &mut [T]) {
    let n = xre.len();
    let s = pass.s;
    let l = n / (2 * s);
    debug_assert_eq!(n % (2 * s), 0);
    let (are, bre) = xre.split_at(n / 2);
    let (aim, bim) = xim.split_at(n / 2);
    if pass.trivial {
        for k in 0..l {
            let i = k * s;
            let o = 2 * k * s;
            for j in 0..s {
                let (ar, ai, br, bi) = (are[i + j], aim[i + j], bre[i + j], bim[i + j]);
                yre[o + j] = ar + br;
                yim[o + j] = ai + bi;
                yre[o + s + j] = ar - br;
                yim[o + s + j] = ai - bi;
            }
        }
    } else {
        let tab = &pass.tables[0];
        for k in 0..l {
            let base_in = k * s;
            let base_out = 2 * k * s;
            let ar = &are[base_in..base_in + s];
            let ai = &aim[base_in..base_in + s];
            let br = &bre[base_in..base_in + s];
            let bi = &bim[base_in..base_in + s];
            let (yar, ybr) = yre[base_out..base_out + 2 * s].split_at_mut(s);
            let (yai, ybi) = yim[base_out..base_out + 2 * s].split_at_mut(s);
            for j in 0..s {
                let (a_r, a_i, b_r, b_i) = ratio(
                    ar[j], ai[j], br[j], bi[j],
                    tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j],
                );
                yar[j] = a_r;
                yai[j] = a_i;
                ybr[j] = b_r;
                ybi[j] = b_i;
            }
        }
    }
}

fn pass3<T: Real>(
    pass: &PassTables<T>,
    fwd: bool,
    xre: &[T],
    xim: &[T],
    yre: &mut [T],
    yim: &mut [T],
) {
    let n = xre.len();
    let s = pass.s;
    let l = n / (3 * s);
    let seg = n / 3;
    debug_assert_eq!(n % (3 * s), 0);
    if pass.trivial {
        for k in 0..l {
            for j in 0..s {
                let i0 = k * s + j;
                let u = dft3(
                    (xre[i0], xim[i0]),
                    (xre[i0 + seg], xim[i0 + seg]),
                    (xre[i0 + 2 * seg], xim[i0 + 2 * seg]),
                    fwd,
                );
                scatter(yre, yim, 3 * k * s + j, s, &u);
            }
        }
    } else {
        let (t1, t2) = (&pass.tables[0], &pass.tables[1]);
        for k in 0..l {
            for j in 0..s {
                let i0 = k * s + j;
                let z1 = ratio_twiddle_mul(
                    xre[i0 + seg], xim[i0 + seg],
                    t1.m1[j], t1.m2[j], t1.t[j], t1.sel[j],
                );
                let z2 = ratio_twiddle_mul(
                    xre[i0 + 2 * seg], xim[i0 + 2 * seg],
                    t2.m1[j], t2.m2[j], t2.t[j], t2.sel[j],
                );
                let u = dft3((xre[i0], xim[i0]), z1, z2, fwd);
                scatter(yre, yim, 3 * k * s + j, s, &u);
            }
        }
    }
}

fn pass4<T: Real>(
    pass: &PassTables<T>,
    fwd: bool,
    xre: &[T],
    xim: &[T],
    yre: &mut [T],
    yim: &mut [T],
) {
    let n = xre.len();
    let s = pass.s;
    let l = n / (4 * s);
    let seg = n / 4;
    debug_assert_eq!(n % (4 * s), 0);
    if pass.trivial {
        for k in 0..l {
            for j in 0..s {
                let i0 = k * s + j;
                let u = dft4(
                    (xre[i0], xim[i0]),
                    (xre[i0 + seg], xim[i0 + seg]),
                    (xre[i0 + 2 * seg], xim[i0 + 2 * seg]),
                    (xre[i0 + 3 * seg], xim[i0 + 3 * seg]),
                    fwd,
                );
                scatter(yre, yim, 4 * k * s + j, s, &u);
            }
        }
    } else {
        let (t1, t2, t3) = (&pass.tables[0], &pass.tables[1], &pass.tables[2]);
        for k in 0..l {
            for j in 0..s {
                let i0 = k * s + j;
                let z1 = ratio_twiddle_mul(
                    xre[i0 + seg], xim[i0 + seg],
                    t1.m1[j], t1.m2[j], t1.t[j], t1.sel[j],
                );
                let z2 = ratio_twiddle_mul(
                    xre[i0 + 2 * seg], xim[i0 + 2 * seg],
                    t2.m1[j], t2.m2[j], t2.t[j], t2.sel[j],
                );
                let z3 = ratio_twiddle_mul(
                    xre[i0 + 3 * seg], xim[i0 + 3 * seg],
                    t3.m1[j], t3.m2[j], t3.t[j], t3.sel[j],
                );
                let u = dft4((xre[i0], xim[i0]), z1, z2, z3, fwd);
                scatter(yre, yim, 4 * k * s + j, s, &u);
            }
        }
    }
}

fn pass8<T: Real>(
    pass: &PassTables<T>,
    fwd: bool,
    xre: &[T],
    xim: &[T],
    yre: &mut [T],
    yim: &mut [T],
) {
    let n = xre.len();
    let s = pass.s;
    let l = n / (8 * s);
    let seg = n / 8;
    debug_assert_eq!(n % (8 * s), 0);
    if pass.trivial {
        for k in 0..l {
            for j in 0..s {
                let i0 = k * s + j;
                let z: [(T, T); 8] =
                    core::array::from_fn(|q| (xre[i0 + q * seg], xim[i0 + q * seg]));
                let u = dft8(z, fwd);
                scatter(yre, yim, 8 * k * s + j, s, &u);
            }
        }
    } else {
        for k in 0..l {
            for j in 0..s {
                let i0 = k * s + j;
                let z: [(T, T); 8] = core::array::from_fn(|q| {
                    if q == 0 {
                        (xre[i0], xim[i0])
                    } else {
                        let tab = &pass.tables[q - 1];
                        ratio_twiddle_mul(
                            xre[i0 + q * seg], xim[i0 + q * seg],
                            tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j],
                        )
                    }
                });
                let u = dft8(z, fwd);
                scatter(yre, yim, 8 * k * s + j, s, &u);
            }
        }
    }
}

/// Scatter `u[m]` to `y[base + m·s]` — the autosort interleave.
#[inline(always)]
fn scatter<T: Real, const R: usize>(yre: &mut [T], yim: &mut [T], base: usize, s: usize, u: &[(T, T); R]) {
    for (m, &(ur, ui)) in u.iter().enumerate() {
        yre[base + m * s] = ur;
        yim[base + m * s] = ui;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{Direction, Strategy};
    use crate::util::prng::Pcg32;

    /// Run a whole schedule through `run_pass` ping-pong (test-local
    /// driver; the real one lives in [`super::super::plan`]).
    fn run_schedule(
        n: usize,
        radices: &[usize],
        strategy: Strategy,
        dir: Direction,
        re: &[f64],
        im: &[f64],
    ) -> (Vec<f64>, Vec<f64>) {
        let passes = crate::kernel::twiddles::build_passes::<f64>(n, radices, dir, strategy);
        let fwd = dir == Direction::Forward;
        let mut a = (re.to_vec(), im.to_vec());
        let mut b = (vec![0.0; n], vec![0.0; n]);
        for pass in &passes {
            run_pass(pass, fwd, &a.0, &a.1, &mut b.0, &mut b.1);
            core::mem::swap(&mut a, &mut b);
        }
        if dir == Direction::Inverse {
            for x in a.0.iter_mut().chain(a.1.iter_mut()) {
                *x /= n as f64;
            }
        }
        a
    }

    #[test]
    fn every_radix_order_matches_the_dft_oracle() {
        // The Stockham recurrence is order-free: any permutation of
        // the same radices computes the same DFT.
        let mut rng = Pcg32::seed(21);
        let cases: &[(usize, &[usize])] = &[
            (6, &[3, 2]),
            (6, &[2, 3]),
            (24, &[3, 8]),
            (24, &[8, 3]),
            (24, &[2, 3, 4]),
            (48, &[3, 4, 4]),
            (96, &[3, 8, 4]),
            (96, &[4, 8, 3]),
            (1536, &[3, 8, 8, 8]),
        ];
        for &(n, radices) in cases {
            let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let (wr, wi) = crate::dft::naive_dft(&re, &im, false);
            let (gr, gi) = run_schedule(n, radices, Strategy::DualSelect, Direction::Forward, &re, &im);
            let err = crate::util::metrics::rel_l2(&gr, &gi, &wr, &wi);
            assert!(err < 1e-12, "n={n} radices={radices:?} err={err:.3e}");
        }
    }

    #[test]
    fn inverse_roundtrips_through_any_schedule() {
        let mut rng = Pcg32::seed(22);
        let n = 144usize; // 2^4 · 3^2
        let radices = [3, 3, 4, 4];
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let (fr, fi) = run_schedule(n, &radices, Strategy::DualSelect, Direction::Forward, &re, &im);
        let (gr, gi) = run_schedule(n, &radices, Strategy::DualSelect, Direction::Inverse, &fr, &fi);
        assert!(crate::util::metrics::rel_l2(&gr, &gi, &re, &im) < 1e-12);
    }

    #[test]
    fn radix2_pass_is_bit_identical_to_the_classic_plan_pass() {
        use crate::fft::plan::{PassKind, Plan};
        let n = 128usize;
        let mut rng = Pcg32::seed(23);
        let plan = Plan::<f32>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        for (p, table) in plan.passes.iter().enumerate() {
            let PassKind::Ratio(_) = &table.kind else {
                panic!("ratio strategies build ratio passes")
            };
            let pass = crate::kernel::twiddles::PassTables::<f32>::build(
                n, 2, table.s, Direction::Forward, Strategy::DualSelect,
            );
            assert_eq!(pass.trivial, table.trivial, "p={p}");
            let xre: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
            let xim: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
            let (mut yr0, mut yi0) = (vec![0.0f32; n], vec![0.0f32; n]);
            let (mut yr1, mut yi1) = (vec![0.0f32; n], vec![0.0f32; n]);
            crate::fft::stockham::run_pass(table, &xre, &xim, &mut yr0, &mut yi0);
            run_pass(&pass, true, &xre, &xim, &mut yr1, &mut yi1);
            assert_eq!(yr0, yr1, "re plane diverged at pass {p}");
            assert_eq!(yi0, yi1, "im plane diverged at pass {p}");
        }
    }

    #[test]
    fn clamped_baselines_run_but_carry_clamp_damage() {
        let mut rng = Pcg32::seed(24);
        let n = 48usize;
        let re: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let im: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let (wr, wi) = crate::dft::naive_dft(&re, &im, false);
        let (gr, gi) = run_schedule(n, &[3, 4, 4], Strategy::LinzerFeig, Direction::Forward, &re, &im);
        let err = crate::util::metrics::rel_l2(&gr, &gi, &wr, &wi);
        assert!(err < 5e-6, "lf err {err:.3e}"); // finite, but clamp-limited
        assert!(err > 1e-12, "clamped W^0 must show up in f64");
    }
}
