//! AVX2/FMA dispatch arm of the mixed-radix engine.
//!
//! Bit-identity is the design constraint, not an accident: every
//! vector instruction here is the 8-lane (f32) or 4-lane (f64) image
//! of one scalar operation in [`super::passes`] /
//! [`super::butterflies`] / [`crate::fft::butterfly`]:
//!
//! * `x.mul_add(y, acc)`  → `vfmadd`   (one rounding either way)
//! * `x.mul_add(-y, acc)` → `vfnmadd`  (`x·(-y)+a ≡ -(x·y)+a` exactly)
//! * `+` / `-` / `*`      → `vadd` / `vsub` / `vmul`
//! * unary `-`            → sign-bit XOR (exact, no rounding)
//! * the dual-select operand swap → `vblendv` on a mask computed from
//!   the 0/1 `selm` plane (`selm[j] > 0.5`), which picks per lane
//!   exactly what the scalar `if sel { .. }` picks per element
//!
//! Each output element of a pass depends only on its own gather
//! column, so vectorizing the `j` loop changes evaluation *order* but
//! not any dataflow, and lane-for-lane identical ops give bit-for-bit
//! identical planes.  `tests/kernel_plane.rs` enforces this against
//! the portable arm on every supported size and dtype.
//!
//! Only the stride loop (`j`) is vectorized; blocks with `s` smaller
//! than the lane width (in practice only the first, twiddle-free
//! passes of a plan) and loop remainders run the scalar per-element
//! code verbatim.
//!
//! On non-x86_64 targets [`simd_available`] is `false` and the
//! dispatcher never routes here; the entry point is compiled out to
//! an `unreachable!`.

use core::any::TypeId;

use crate::precision::Real;

use super::twiddles::PassTables;

/// True when the SIMD arm can serve element type `T` on this host:
/// x86_64 with AVX2 and FMA detected at runtime, `T` ∈ {f32, f64}.
/// (f16/bf16 ingest reaches the kernel through the dtype-erased f32
/// arm of `AnyTransform`, so the soft formats never dispatch here.)
pub fn simd_available<T: Real>() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        let ty = TypeId::of::<T>();
        (ty == TypeId::of::<f32>() || ty == TypeId::of::<f64>())
            && std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Run one pass on the SIMD arm.  Panics if [`simd_available::<T>`]
/// is false — the plan constructor only selects this arm after
/// checking, so hitting the panic means a dispatch bug, not a user
/// error.
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn run_pass_simd<T: Real>(
    pass: &PassTables<T>,
    fwd: bool,
    xre: &[T],
    xim: &[T],
    yre: &mut [T],
    yim: &mut [T],
) {
    assert!(
        simd_available::<T>(),
        "SIMD arm dispatched without AVX2+FMA or for a soft float type"
    );
    #[cfg(target_arch = "x86_64")]
    {
        let ty = TypeId::of::<T>();
        if ty == TypeId::of::<f32>() {
            // SAFETY: TypeId equality proves T == f32, so every cast
            // below is an identity cast; `run_pass` requires AVX2+FMA,
            // established by the `simd_available` assert above.
            unsafe {
                x86::f32_lanes::run_pass(
                    cast_pass::<T, f32>(pass),
                    fwd,
                    cast_slice::<T, f32>(xre),
                    cast_slice::<T, f32>(xim),
                    cast_slice_mut::<T, f32>(yre),
                    cast_slice_mut::<T, f32>(yim),
                )
            }
        } else {
            // SAFETY: as above with T == f64 (`simd_available` admits
            // only f32 and f64, and the f32 case was handled).
            unsafe {
                x86::f64_lanes::run_pass(
                    cast_pass::<T, f64>(pass),
                    fwd,
                    cast_slice::<T, f64>(xre),
                    cast_slice::<T, f64>(xim),
                    cast_slice_mut::<T, f64>(yre),
                    cast_slice_mut::<T, f64>(yim),
                )
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        unreachable!("simd_available is false off x86_64; dispatch must pick the portable arm")
    }
}

/// Identity-cast a slice once `TypeId` has proven `T == U`.
#[cfg(target_arch = "x86_64")]
fn cast_slice<T: 'static, U: 'static>(x: &[T]) -> &[U] {
    assert_eq!(TypeId::of::<T>(), TypeId::of::<U>());
    // SAFETY: T and U are the same type (checked above), so layout,
    // validity and lifetime are trivially preserved.
    unsafe { core::slice::from_raw_parts(x.as_ptr() as *const U, x.len()) }
}

/// Identity-cast a mutable slice once `TypeId` has proven `T == U`.
#[cfg(target_arch = "x86_64")]
fn cast_slice_mut<T: 'static, U: 'static>(x: &mut [T]) -> &mut [U] {
    assert_eq!(TypeId::of::<T>(), TypeId::of::<U>());
    // SAFETY: identity cast, as in `cast_slice`; the &mut borrow is
    // moved, never duplicated.
    unsafe { core::slice::from_raw_parts_mut(x.as_mut_ptr() as *mut U, x.len()) }
}

/// Identity-cast a pass-table reference once `TypeId` has proven
/// `T == U`.
#[cfg(target_arch = "x86_64")]
fn cast_pass<T: Real, U: Real>(p: &PassTables<T>) -> &PassTables<U> {
    assert_eq!(TypeId::of::<T>(), TypeId::of::<U>());
    // SAFETY: PassTables<T> and PassTables<U> are the same type when
    // T == U (checked above).
    unsafe { &*(p as *const PassTables<T> as *const PassTables<U>) }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    macro_rules! lanes_impl {
        (
            $modname:ident, $elem:ty, $vec:ty, $lanes:expr,
            $loadu:ident, $storeu:ident, $set1:ident,
            $add:ident, $sub:ident, $mul:ident, $xor:ident,
            $fmadd:ident, $fnmadd:ident, $blendv:ident, $cmp:ident
        ) => {
            pub mod $modname {
                use core::arch::x86_64::*;

                use crate::fft::butterfly::{ratio, ratio_twiddle_mul};
                use crate::kernel::butterflies::{dft3, dft4, dft8, FRAC_1_SQRT_2, SQRT3_2};
                use crate::kernel::twiddles::PassTables;

                const LANES: usize = $lanes;

                #[inline(always)]
                unsafe fn ld(x: &[$elem], i: usize) -> $vec {
                    debug_assert!(i + LANES <= x.len());
                    // SAFETY: caller keeps i + LANES <= x.len().
                    unsafe { $loadu(x.as_ptr().add(i)) }
                }

                #[inline(always)]
                unsafe fn st(y: &mut [$elem], i: usize, v: $vec) {
                    debug_assert!(i + LANES <= y.len());
                    // SAFETY: caller keeps i + LANES <= y.len().
                    unsafe { $storeu(y.as_mut_ptr().add(i), v) }
                }

                /// Sign-bit flip — the vector image of scalar unary `-`.
                #[inline(always)]
                unsafe fn neg(x: $vec) -> $vec {
                    unsafe { $xor(x, $set1(-0.0)) }
                }

                /// Lane image of [`crate::fft::butterfly::ratio`]:
                /// blendv swap, 2 FMA shears, 4 FMA combines.
                #[inline(always)]
                #[allow(clippy::too_many_arguments)]
                unsafe fn bf_ratio(
                    ar: $vec, ai: $vec, br: $vec, bi: $vec,
                    m1: $vec, m2: $vec, t: $vec, mask: $vec,
                ) -> ($vec, $vec, $vec, $vec) {
                    unsafe {
                        let u = $blendv(bi, br, mask); // sel ? br : bi
                        let v = $blendv(br, bi, mask); // sel ? bi : br
                        let s1 = $fnmadd(t, v, u); // t.mul_add(-v, u)
                        let s2 = $fmadd(t, u, v); //  t.mul_add(u, v)
                        (
                            $fmadd(m1, s1, ar),
                            $fmadd(m2, s2, ai),
                            $fnmadd(m1, s1, ar), // (-m1).mul_add(s1, ar)
                            $fnmadd(m2, s2, ai),
                        )
                    }
                }

                /// Lane image of [`crate::fft::butterfly::ratio_twiddle_mul`].
                #[inline(always)]
                unsafe fn tw_mul(
                    zr: $vec, zi: $vec, m1: $vec, m2: $vec, t: $vec, mask: $vec,
                ) -> ($vec, $vec) {
                    unsafe {
                        let u = $blendv(zi, zr, mask);
                        let v = $blendv(zr, zi, mask);
                        ($mul(m1, $fnmadd(t, v, u)), $mul(m2, $fmadd(t, u, v)))
                    }
                }

                /// Lane image of [`crate::kernel::butterflies::dft3`].
                #[inline(always)]
                unsafe fn dft3v(
                    z0: ($vec, $vec), z1: ($vec, $vec), z2: ($vec, $vec), fwd: bool,
                ) -> [($vec, $vec); 3] {
                    unsafe {
                        let half = $set1(0.5);
                        let c = $set1(SQRT3_2 as $elem);
                        let sr = $add(z1.0, z2.0);
                        let si = $add(z1.1, z2.1);
                        let u0 = ($add(z0.0, sr), $add(z0.1, si));
                        let mr = $fnmadd(half, sr, z0.0); // half.mul_add(-sr, z0r)
                        let mi = $fnmadd(half, si, z0.1);
                        let dr = $sub(z1.0, z2.0);
                        let di = $sub(z1.1, z2.1);
                        let (u1, u2) = if fwd {
                            (
                                ($fmadd(c, di, mr), $fnmadd(c, dr, mi)),
                                ($fnmadd(c, di, mr), $fmadd(c, dr, mi)),
                            )
                        } else {
                            (
                                ($fnmadd(c, di, mr), $fmadd(c, dr, mi)),
                                ($fmadd(c, di, mr), $fnmadd(c, dr, mi)),
                            )
                        };
                        [u0, u1, u2]
                    }
                }

                /// Lane image of [`crate::kernel::butterflies::dft4`].
                #[inline(always)]
                unsafe fn dft4v(
                    z0: ($vec, $vec), z1: ($vec, $vec), z2: ($vec, $vec), z3: ($vec, $vec),
                    fwd: bool,
                ) -> [($vec, $vec); 4] {
                    unsafe {
                        let e_r = $add(z0.0, z2.0);
                        let e_i = $add(z0.1, z2.1);
                        let f_r = $sub(z0.0, z2.0);
                        let f_i = $sub(z0.1, z2.1);
                        let g_r = $add(z1.0, z3.0);
                        let g_i = $add(z1.1, z3.1);
                        let h_r = $sub(z1.0, z3.0);
                        let h_i = $sub(z1.1, z3.1);
                        let (jh_r, jh_i) = if fwd { (h_i, neg(h_r)) } else { (neg(h_i), h_r) };
                        [
                            ($add(e_r, g_r), $add(e_i, g_i)),
                            ($add(f_r, jh_r), $add(f_i, jh_i)),
                            ($sub(e_r, g_r), $sub(e_i, g_i)),
                            ($sub(f_r, jh_r), $sub(f_i, jh_i)),
                        ]
                    }
                }

                /// Lane image of [`crate::kernel::butterflies::dft8`].
                #[inline(always)]
                unsafe fn dft8v(z: [($vec, $vec); 8], fwd: bool) -> [($vec, $vec); 8] {
                    unsafe {
                        let c = $set1(FRAC_1_SQRT_2 as $elem);
                        let e = dft4v(z[0], z[2], z[4], z[6], fwd);
                        let o = dft4v(z[1], z[3], z[5], z[7], fwd);
                        let (r1, i1) = o[1];
                        let (r2, i2) = o[2];
                        let (r3, i3) = o[3];
                        let (o1, o2, o3) = if fwd {
                            (
                                ($mul(c, $add(r1, i1)), $mul(c, $sub(i1, r1))),
                                (i2, neg(r2)),
                                ($mul(c, $sub(i3, r3)), neg($mul(c, $add(r3, i3)))),
                            )
                        } else {
                            (
                                ($mul(c, $sub(r1, i1)), $mul(c, $add(i1, r1))),
                                (neg(i2), r2),
                                (neg($mul(c, $add(r3, i3))), $mul(c, $sub(r3, i3))),
                            )
                        };
                        let rot = [o[0], o1, o2, o3];
                        let mut out = [z[0]; 8];
                        for m in 0..4 {
                            out[m] = ($add(e[m].0, rot[m].0), $add(e[m].1, rot[m].1));
                            out[m + 4] = ($sub(e[m].0, rot[m].0), $sub(e[m].1, rot[m].1));
                        }
                        out
                    }
                }

                /// One pass on this lane width.
                ///
                /// # Safety
                /// AVX2 and FMA must be available on the executing CPU
                /// (checked by the dispatcher); slices must all have
                /// length `n` divisible by `pass.radix · pass.s`.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn run_pass(
                    pass: &PassTables<$elem>,
                    fwd: bool,
                    xre: &[$elem],
                    xim: &[$elem],
                    yre: &mut [$elem],
                    yim: &mut [$elem],
                ) {
                    // SAFETY: the per-radix bodies inherit this
                    // function's feature context and slice contract.
                    unsafe {
                        match pass.radix {
                            2 => pass2(pass, xre, xim, yre, yim),
                            3 => pass3(pass, fwd, xre, xim, yre, yim),
                            4 => pass4(pass, fwd, xre, xim, yre, yim),
                            8 => pass8(pass, fwd, xre, xim, yre, yim),
                            r => unreachable!("unsupported radix {r}"),
                        }
                    }
                }

                #[target_feature(enable = "avx2,fma")]
                unsafe fn pass2(
                    pass: &PassTables<$elem>,
                    xre: &[$elem],
                    xim: &[$elem],
                    yre: &mut [$elem],
                    yim: &mut [$elem],
                ) {
                    let n = xre.len();
                    let s = pass.s;
                    let l = n / (2 * s);
                    let (are, bre) = xre.split_at(n / 2);
                    let (aim, bim) = xim.split_at(n / 2);
                    if pass.trivial {
                        for k in 0..l {
                            let i = k * s;
                            let o = 2 * k * s;
                            let mut j = 0usize;
                            while j + LANES <= s {
                                // SAFETY: j + LANES <= s keeps every
                                // offset in bounds.
                                unsafe {
                                    let ar = ld(are, i + j);
                                    let ai = ld(aim, i + j);
                                    let br = ld(bre, i + j);
                                    let bi = ld(bim, i + j);
                                    st(yre, o + j, $add(ar, br));
                                    st(yim, o + j, $add(ai, bi));
                                    st(yre, o + s + j, $sub(ar, br));
                                    st(yim, o + s + j, $sub(ai, bi));
                                }
                                j += LANES;
                            }
                            while j < s {
                                let (ar, ai, br, bi) =
                                    (are[i + j], aim[i + j], bre[i + j], bim[i + j]);
                                yre[o + j] = ar + br;
                                yim[o + j] = ai + bi;
                                yre[o + s + j] = ar - br;
                                yim[o + s + j] = ai - bi;
                                j += 1;
                            }
                        }
                    } else {
                        let tab = &pass.tables[0];
                        let selm = &pass.selm[0];
                        for k in 0..l {
                            let i = k * s;
                            let o = 2 * k * s;
                            let mut j = 0usize;
                            while j + LANES <= s {
                                // SAFETY: j + LANES <= s; table planes
                                // have length s by construction.
                                unsafe {
                                    let half = $set1(0.5);
                                    let mask = $cmp::<_CMP_GT_OQ>(ld(selm, j), half);
                                    let (a_r, a_i, b_r, b_i) = bf_ratio(
                                        ld(are, i + j), ld(aim, i + j),
                                        ld(bre, i + j), ld(bim, i + j),
                                        ld(&tab.m1, j), ld(&tab.m2, j), ld(&tab.t, j), mask,
                                    );
                                    st(yre, o + j, a_r);
                                    st(yim, o + j, a_i);
                                    st(yre, o + s + j, b_r);
                                    st(yim, o + s + j, b_i);
                                }
                                j += LANES;
                            }
                            while j < s {
                                let (a_r, a_i, b_r, b_i) = ratio(
                                    are[i + j], aim[i + j], bre[i + j], bim[i + j],
                                    tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j],
                                );
                                yre[o + j] = a_r;
                                yim[o + j] = a_i;
                                yre[o + s + j] = b_r;
                                yim[o + s + j] = b_i;
                                j += 1;
                            }
                        }
                    }
                }

                #[target_feature(enable = "avx2,fma")]
                unsafe fn pass3(
                    pass: &PassTables<$elem>,
                    fwd: bool,
                    xre: &[$elem],
                    xim: &[$elem],
                    yre: &mut [$elem],
                    yim: &mut [$elem],
                ) {
                    let n = xre.len();
                    let s = pass.s;
                    let l = n / (3 * s);
                    let seg = n / 3;
                    for k in 0..l {
                        let i0 = k * s;
                        let o = 3 * k * s;
                        let mut j = 0usize;
                        while j + LANES <= s {
                            // SAFETY: j + LANES <= s keeps gather and
                            // scatter offsets in bounds.
                            unsafe {
                                let z0 = (ld(xre, i0 + j), ld(xim, i0 + j));
                                let (z1, z2) = if pass.trivial {
                                    (
                                        (ld(xre, i0 + seg + j), ld(xim, i0 + seg + j)),
                                        (ld(xre, i0 + 2 * seg + j), ld(xim, i0 + 2 * seg + j)),
                                    )
                                } else {
                                    let half = $set1(0.5);
                                    let (t1, t2) = (&pass.tables[0], &pass.tables[1]);
                                    let m1 = $cmp::<_CMP_GT_OQ>(ld(&pass.selm[0], j), half);
                                    let m2 = $cmp::<_CMP_GT_OQ>(ld(&pass.selm[1], j), half);
                                    (
                                        tw_mul(
                                            ld(xre, i0 + seg + j), ld(xim, i0 + seg + j),
                                            ld(&t1.m1, j), ld(&t1.m2, j), ld(&t1.t, j), m1,
                                        ),
                                        tw_mul(
                                            ld(xre, i0 + 2 * seg + j), ld(xim, i0 + 2 * seg + j),
                                            ld(&t2.m1, j), ld(&t2.m2, j), ld(&t2.t, j), m2,
                                        ),
                                    )
                                };
                                let u = dft3v(z0, z1, z2, fwd);
                                for (m, &(ur, ui)) in u.iter().enumerate() {
                                    st(yre, o + m * s + j, ur);
                                    st(yim, o + m * s + j, ui);
                                }
                            }
                            j += LANES;
                        }
                        while j < s {
                            let i = i0 + j;
                            let z0 = (xre[i], xim[i]);
                            let (z1, z2) = if pass.trivial {
                                ((xre[i + seg], xim[i + seg]), (xre[i + 2 * seg], xim[i + 2 * seg]))
                            } else {
                                let (t1, t2) = (&pass.tables[0], &pass.tables[1]);
                                (
                                    ratio_twiddle_mul(
                                        xre[i + seg], xim[i + seg],
                                        t1.m1[j], t1.m2[j], t1.t[j], t1.sel[j],
                                    ),
                                    ratio_twiddle_mul(
                                        xre[i + 2 * seg], xim[i + 2 * seg],
                                        t2.m1[j], t2.m2[j], t2.t[j], t2.sel[j],
                                    ),
                                )
                            };
                            let u = dft3(z0, z1, z2, fwd);
                            for (m, &(ur, ui)) in u.iter().enumerate() {
                                yre[o + m * s + j] = ur;
                                yim[o + m * s + j] = ui;
                            }
                            j += 1;
                        }
                    }
                }

                #[target_feature(enable = "avx2,fma")]
                unsafe fn pass4(
                    pass: &PassTables<$elem>,
                    fwd: bool,
                    xre: &[$elem],
                    xim: &[$elem],
                    yre: &mut [$elem],
                    yim: &mut [$elem],
                ) {
                    let n = xre.len();
                    let s = pass.s;
                    let l = n / (4 * s);
                    let seg = n / 4;
                    for k in 0..l {
                        let i0 = k * s;
                        let o = 4 * k * s;
                        let mut j = 0usize;
                        while j + LANES <= s {
                            // SAFETY: j + LANES <= s keeps gather and
                            // scatter offsets in bounds.
                            unsafe {
                                let z: [($vec, $vec); 4] = if pass.trivial {
                                    core::array::from_fn(|q| {
                                        (ld(xre, i0 + q * seg + j), ld(xim, i0 + q * seg + j))
                                    })
                                } else {
                                    let half = $set1(0.5);
                                    core::array::from_fn(|q| {
                                        if q == 0 {
                                            (ld(xre, i0 + j), ld(xim, i0 + j))
                                        } else {
                                            let tab = &pass.tables[q - 1];
                                            let mask = $cmp::<_CMP_GT_OQ>(
                                                ld(&pass.selm[q - 1], j), half,
                                            );
                                            tw_mul(
                                                ld(xre, i0 + q * seg + j),
                                                ld(xim, i0 + q * seg + j),
                                                ld(&tab.m1, j), ld(&tab.m2, j), ld(&tab.t, j),
                                                mask,
                                            )
                                        }
                                    })
                                };
                                let u = dft4v(z[0], z[1], z[2], z[3], fwd);
                                for (m, &(ur, ui)) in u.iter().enumerate() {
                                    st(yre, o + m * s + j, ur);
                                    st(yim, o + m * s + j, ui);
                                }
                            }
                            j += LANES;
                        }
                        while j < s {
                            let i = i0 + j;
                            let z: [($elem, $elem); 4] = if pass.trivial {
                                core::array::from_fn(|q| (xre[i + q * seg], xim[i + q * seg]))
                            } else {
                                core::array::from_fn(|q| {
                                    if q == 0 {
                                        (xre[i], xim[i])
                                    } else {
                                        let tab = &pass.tables[q - 1];
                                        ratio_twiddle_mul(
                                            xre[i + q * seg], xim[i + q * seg],
                                            tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j],
                                        )
                                    }
                                })
                            };
                            let u = dft4(z[0], z[1], z[2], z[3], fwd);
                            for (m, &(ur, ui)) in u.iter().enumerate() {
                                yre[o + m * s + j] = ur;
                                yim[o + m * s + j] = ui;
                            }
                            j += 1;
                        }
                    }
                }

                #[target_feature(enable = "avx2,fma")]
                unsafe fn pass8(
                    pass: &PassTables<$elem>,
                    fwd: bool,
                    xre: &[$elem],
                    xim: &[$elem],
                    yre: &mut [$elem],
                    yim: &mut [$elem],
                ) {
                    let n = xre.len();
                    let s = pass.s;
                    let l = n / (8 * s);
                    let seg = n / 8;
                    for k in 0..l {
                        let i0 = k * s;
                        let o = 8 * k * s;
                        let mut j = 0usize;
                        while j + LANES <= s {
                            // SAFETY: j + LANES <= s keeps gather and
                            // scatter offsets in bounds.
                            unsafe {
                                let z: [($vec, $vec); 8] = if pass.trivial {
                                    core::array::from_fn(|q| {
                                        (ld(xre, i0 + q * seg + j), ld(xim, i0 + q * seg + j))
                                    })
                                } else {
                                    let half = $set1(0.5);
                                    core::array::from_fn(|q| {
                                        if q == 0 {
                                            (ld(xre, i0 + j), ld(xim, i0 + j))
                                        } else {
                                            let tab = &pass.tables[q - 1];
                                            let mask = $cmp::<_CMP_GT_OQ>(
                                                ld(&pass.selm[q - 1], j), half,
                                            );
                                            tw_mul(
                                                ld(xre, i0 + q * seg + j),
                                                ld(xim, i0 + q * seg + j),
                                                ld(&tab.m1, j), ld(&tab.m2, j), ld(&tab.t, j),
                                                mask,
                                            )
                                        }
                                    })
                                };
                                let u = dft8v(z, fwd);
                                for (m, &(ur, ui)) in u.iter().enumerate() {
                                    st(yre, o + m * s + j, ur);
                                    st(yim, o + m * s + j, ui);
                                }
                            }
                            j += LANES;
                        }
                        while j < s {
                            let i = i0 + j;
                            let z: [($elem, $elem); 8] = if pass.trivial {
                                core::array::from_fn(|q| (xre[i + q * seg], xim[i + q * seg]))
                            } else {
                                core::array::from_fn(|q| {
                                    if q == 0 {
                                        (xre[i], xim[i])
                                    } else {
                                        let tab = &pass.tables[q - 1];
                                        ratio_twiddle_mul(
                                            xre[i + q * seg], xim[i + q * seg],
                                            tab.m1[j], tab.m2[j], tab.t[j], tab.sel[j],
                                        )
                                    }
                                })
                            };
                            let u = dft8(z, fwd);
                            for (m, &(ur, ui)) in u.iter().enumerate() {
                                yre[o + m * s + j] = ur;
                                yim[o + m * s + j] = ui;
                            }
                            j += 1;
                        }
                    }
                }
            }
        };
    }

    lanes_impl!(
        f32_lanes, f32, __m256, 8,
        _mm256_loadu_ps, _mm256_storeu_ps, _mm256_set1_ps,
        _mm256_add_ps, _mm256_sub_ps, _mm256_mul_ps, _mm256_xor_ps,
        _mm256_fmadd_ps, _mm256_fnmadd_ps, _mm256_blendv_ps, _mm256_cmp_ps
    );
    lanes_impl!(
        f64_lanes, f64, __m256d, 4,
        _mm256_loadu_pd, _mm256_storeu_pd, _mm256_set1_pd,
        _mm256_add_pd, _mm256_sub_pd, _mm256_mul_pd, _mm256_xor_pd,
        _mm256_fmadd_pd, _mm256_fnmadd_pd, _mm256_blendv_pd, _mm256_cmp_pd
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{Direction, Strategy};
    use crate::kernel::twiddles::build_passes;
    use crate::util::prng::Pcg32;

    fn check_bit_identity<T: Real>(n: usize, radices: &[usize], strategy: Strategy) {
        if !simd_available::<T>() {
            return; // nothing to compare against on this host
        }
        let mut rng = Pcg32::seed(n as u64);
        for dir in [Direction::Forward, Direction::Inverse] {
            let passes = build_passes::<T>(n, radices, dir, strategy);
            let fwd = dir == Direction::Forward;
            let xre: Vec<T> = (0..n).map(|_| T::from_f64(rng.gaussian())).collect();
            let xim: Vec<T> = (0..n).map(|_| T::from_f64(rng.gaussian())).collect();
            let zero = vec![T::zero(); n];
            // Feed each pass the previous *portable* output so both
            // arms see identical inputs at every depth.
            let (mut cre, mut cim) = (xre, xim);
            for (p, pass) in passes.iter().enumerate() {
                let (mut pr, mut pi) = (zero.clone(), zero.clone());
                let (mut vr, mut vi) = (zero.clone(), zero.clone());
                crate::kernel::passes::run_pass(pass, fwd, &cre, &cim, &mut pr, &mut pi);
                run_pass_simd(pass, fwd, &cre, &cim, &mut vr, &mut vi);
                assert_eq!(pr, vr, "{} re plane pass {p} s={}", T::NAME, pass.s);
                assert_eq!(pi, vi, "{} im plane pass {p} s={}", T::NAME, pass.s);
                (cre, cim) = (pr, pi);
            }
        }
    }

    #[test]
    fn simd_passes_bit_identical_to_portable() {
        for strategy in [Strategy::DualSelect, Strategy::LinzerFeig, Strategy::Cosine] {
            check_bit_identity::<f32>(96, &[3, 8, 4], strategy);
            check_bit_identity::<f64>(96, &[3, 8, 4], strategy);
            check_bit_identity::<f32>(1024, &[8, 8, 4, 4], strategy);
            check_bit_identity::<f64>(1024, &[8, 8, 4, 4], strategy);
            check_bit_identity::<f32>(64, &[2, 2, 2, 2, 2, 2], strategy);
            check_bit_identity::<f64>(1536, &[3, 8, 8, 8], strategy);
        }
    }

    #[test]
    fn soft_floats_never_claim_the_simd_arm() {
        assert!(!simd_available::<crate::precision::F16>());
        assert!(!simd_available::<crate::precision::Bf16>());
    }
}
