//! [`MixedRadixPlan`] — the planned, executable face of the
//! mixed-radix engine, implementing [`Transform`] so the serving
//! plane, pipelines and benches drive it like every other plan.
//!
//! Construction factors `n` into the canonical radix schedule
//! ([`super::schedule`]), builds the bounded-ratio twiddle tables per
//! pass ([`super::twiddles`]), and resolves the dispatch arm *once*:
//! the requested [`Kernel`] (plus the `FMAFFT_KERNEL` env override)
//! against what the host actually supports.  Execution then ping-pongs
//! frame ↔ scratch through the passes with zero per-call allocation,
//! exactly like the classic radix-2 plan.

use crate::fft::api::batch::Scratch;
use crate::fft::api::Transform;
use crate::fft::{Direction, FftError, FftResult, Strategy};
use crate::precision::Real;

use super::passes;
use super::schedule::{plan_radices, validate_radices};
use super::simd;
use super::twiddles::{build_passes, PassTables};
use super::{kernel_env_override, note_dispatch, Arm, Kernel};

/// A planned mixed-radix Stockham transform for composite
/// `n = 2^a · 3^b`, with the dispatch arm (portable scalar vs.
/// AVX2/FMA) frozen at build time.
#[derive(Clone, Debug)]
pub struct MixedRadixPlan<T: Real> {
    pub n: usize,
    pub strategy: Strategy,
    pub direction: Direction,
    /// Per-pass butterfly radices, in execution order.
    pub radices: Vec<usize>,
    passes: Vec<PassTables<T>>,
    kernel: Kernel,
    arm: Arm,
}

impl<T: Real> MixedRadixPlan<T> {
    /// Plan with the canonical radix schedule and automatic kernel
    /// dispatch (SIMD when the host supports it).
    pub fn new(n: usize, strategy: Strategy, direction: Direction) -> FftResult<Self> {
        Self::with_kernel(n, strategy, direction, Kernel::Auto)
    }

    /// Plan with the canonical radix schedule and an explicit kernel
    /// request.  [`Kernel::Simd`] fails with [`FftError::Unsupported`]
    /// on hosts (or element types) the SIMD arm cannot serve.
    pub fn with_kernel(
        n: usize,
        strategy: Strategy,
        direction: Direction,
        kernel: Kernel,
    ) -> FftResult<Self> {
        let radices = plan_radices(n)?;
        Self::with_radices(n, &radices, strategy, direction, kernel)
    }

    /// Plan with an explicit radix schedule (must multiply to `n`).
    /// A `[2, 2, ...]` schedule reproduces the classic radix-2 plan
    /// bit for bit — the ablation hook tests/kernel_plane.rs leans on.
    pub fn with_radices(
        n: usize,
        radices: &[usize],
        strategy: Strategy,
        direction: Direction,
        kernel: Kernel,
    ) -> FftResult<Self> {
        if strategy == Strategy::Standard {
            return Err(FftError::UnsupportedStrategy {
                strategy,
                reason: "mixed-radix kernel stores twiddles in ratio form; \
                         use lf, cos or dual",
            });
        }
        validate_radices(n, radices)?;
        let arm = resolve_arm::<T>(kernel)?;
        let passes = build_passes::<T>(n, radices, direction, strategy);
        Ok(MixedRadixPlan {
            n,
            strategy,
            direction,
            radices: radices.to_vec(),
            passes,
            kernel,
            arm,
        })
    }

    /// The kernel variant that was *requested* at build time.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The dispatch arm that was *resolved* at build time.
    pub fn arm(&self) -> Arm {
        self.arm
    }

    /// True when frames execute on the AVX2/FMA arm.
    pub fn uses_simd(&self) -> bool {
        self.arm == Arm::Simd
    }

    pub fn num_passes(&self) -> usize {
        self.passes.len()
    }

    /// Max |ratio| across every twiddle table, as stored (the paper's
    /// Theorem 1 quantity: ≤ 1 for dual-select at every radix).
    pub fn max_ratio(&self) -> f64 {
        let mut worst = 0.0f64;
        for pass in &self.passes {
            for tab in &pass.tables {
                for &t in &tab.t {
                    worst = worst.max(t.to_f64().abs());
                }
            }
        }
        worst
    }

    /// Bytes held by the precomputed twiddle tables.
    pub fn table_bytes(&self) -> usize {
        self.passes.iter().map(|p| p.table_bytes()).sum()
    }

    /// Full transform over borrowed planar slices, ping-ponging with
    /// the caller's scratch planes; result lands in `re`/`im`, with
    /// the 1/n fold applied for inverse plans.  Mirrors
    /// [`crate::fft::stockham::execute_in`].
    pub fn execute_in(&self, re: &mut [T], im: &mut [T], sre: &mut [T], sim: &mut [T]) {
        let n = self.n;
        assert_eq!(re.len(), n, "buffer length != plan size");
        assert_eq!(im.len(), n, "buffer length != plan size");
        assert_eq!(sre.len(), n, "scratch length != plan size");
        assert_eq!(sim.len(), n, "scratch length != plan size");

        note_dispatch(self.arm);
        let fwd = self.direction == Direction::Forward;
        let mut src_in_frame = self.passes.len() % 2 == 0;
        if !src_in_frame {
            sre.copy_from_slice(re);
            sim.copy_from_slice(im);
        }
        for pass in &self.passes {
            if src_in_frame {
                self.run_one(pass, fwd, re, im, sre, sim);
            } else {
                self.run_one(pass, fwd, sre, sim, re, im);
            }
            src_in_frame = !src_in_frame;
        }
        debug_assert!(src_in_frame, "result must end in the frame");

        if self.direction == Direction::Inverse {
            let inv_n = T::from_f64(1.0 / n as f64);
            for x in re.iter_mut() {
                *x = *x * inv_n;
            }
            for x in im.iter_mut() {
                *x = *x * inv_n;
            }
        }
    }

    #[inline]
    fn run_one(
        &self,
        pass: &PassTables<T>,
        fwd: bool,
        xre: &[T],
        xim: &[T],
        yre: &mut [T],
        yim: &mut [T],
    ) {
        match self.arm {
            Arm::Portable => passes::run_pass(pass, fwd, xre, xim, yre, yim),
            Arm::Simd => simd::run_pass_simd(pass, fwd, xre, xim, yre, yim),
        }
    }
}

/// Resolve a kernel request to a dispatch arm for element type `T`,
/// honoring the `FMAFFT_KERNEL` environment override (which caps
/// `Auto`/`Simd` requests down to the portable arm when set to
/// `scalar`, and upgrades `Auto` to a hard SIMD request when set to
/// `simd`).
fn resolve_arm<T: Real>(kernel: Kernel) -> FftResult<Arm> {
    let effective = match kernel_env_override() {
        Some(Kernel::Scalar) => Kernel::Scalar,
        Some(Kernel::Simd) if kernel == Kernel::Auto => Kernel::Simd,
        _ => kernel,
    };
    match effective {
        Kernel::Scalar => Ok(Arm::Portable),
        Kernel::Simd => {
            if simd::simd_available::<T>() {
                Ok(Arm::Simd)
            } else {
                Err(FftError::Unsupported(
                    "SIMD kernel requested but AVX2+FMA is unavailable on this host \
                     (or the element type has no vector arm)",
                ))
            }
        }
        Kernel::Auto => {
            if simd::simd_available::<T>() {
                Ok(Arm::Simd)
            } else {
                Ok(Arm::Portable)
            }
        }
    }
}

impl<T: Real> Transform<T> for MixedRadixPlan<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        self.strategy
    }
    fn direction(&self) -> Direction {
        self.direction
    }
    fn execute_frame(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        let mut work = scratch.take(self.n);
        self.execute_in(re, im, &mut work.re, &mut work.im);
        scratch.put(work);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dft;
    use crate::precision::{Bf16, SplitBuf, F16};
    use crate::util::metrics::rel_l2;
    use crate::util::prng::Pcg32;

    fn random_signal(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Pcg32::seed(seed);
        (
            (0..n).map(|_| rng.gaussian()).collect(),
            (0..n).map(|_| rng.gaussian()).collect(),
        )
    }

    fn run<T: Real>(
        plan: &MixedRadixPlan<T>,
        re: &[f64],
        im: &[f64],
    ) -> (Vec<f64>, Vec<f64>) {
        let mut buf = SplitBuf::<T>::from_f64(re, im);
        plan.execute_alloc(&mut buf);
        buf.to_f64()
    }

    #[test]
    fn composite_sizes_match_dft_oracle_f64() {
        for n in [2usize, 6, 12, 16, 27, 48, 64, 96, 144, 768, 1536] {
            let (re, im) = random_signal(n, n as u64);
            let (wr, wi) = dft::naive_dft(&re, &im, false);
            for strategy in [Strategy::DualSelect, Strategy::LinzerFeig, Strategy::Cosine] {
                let plan =
                    MixedRadixPlan::<f64>::new(n, strategy, Direction::Forward).unwrap();
                let (gr, gi) = run(&plan, &re, &im);
                let err = rel_l2(&gr, &gi, &wr, &wi);
                let tol = match strategy {
                    Strategy::DualSelect => 1e-12,
                    _ => 5e-6, // clamp damage, as in the radix-2 plan
                };
                assert!(err < tol, "n={n} {strategy:?} err={err:.3e}");
            }
        }
    }

    #[test]
    fn inverse_roundtrips_composite_sizes() {
        for n in [6usize, 48, 96, 1536] {
            let (re, im) = random_signal(n, 7 + n as u64);
            let fwd = MixedRadixPlan::<f64>::new(n, Strategy::DualSelect, Direction::Forward)
                .unwrap();
            let inv = MixedRadixPlan::<f64>::new(n, Strategy::DualSelect, Direction::Inverse)
                .unwrap();
            let (fr, fi) = run(&fwd, &re, &im);
            let (gr, gi) = run(&inv, &fr, &fi);
            assert!(rel_l2(&gr, &gi, &re, &im) < 1e-12, "n={n}");
        }
    }

    #[test]
    fn f32_roundtrip_error_matches_paper_scale() {
        let n = 1536;
        let (re, im) = random_signal(n, 42);
        let fwd =
            MixedRadixPlan::<f32>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let inv =
            MixedRadixPlan::<f32>::new(n, Strategy::DualSelect, Direction::Inverse).unwrap();
        let (fr, fi) = run(&fwd, &re, &im);
        let (gr, gi) = run(&inv, &fr, &fi);
        assert!(rel_l2(&gr, &gi, &re, &im) < 1e-6);
    }

    #[test]
    fn radix2_schedule_is_bit_identical_to_classic_plan() {
        // Same pass structure + same ratio tables + same butterfly
        // ops = same bits, on either dispatch arm.
        let n = 64usize;
        let radices = vec![2usize; 6];
        let (re, im) = random_signal(n, 5);
        for kernel in [Kernel::Scalar, Kernel::Auto] {
            let kplan = MixedRadixPlan::<f32>::with_radices(
                n, &radices, Strategy::DualSelect, Direction::Forward, kernel,
            )
            .unwrap();
            let cplan =
                crate::fft::Plan::<f32>::new(n, Strategy::DualSelect, Direction::Forward)
                    .unwrap();
            let mut kb = SplitBuf::<f32>::from_f64(&re, &im);
            let mut cb = kb.clone();
            kplan.execute_alloc(&mut kb);
            cplan.execute_alloc(&mut cb);
            assert_eq!(kb, cb, "kernel={kernel:?} arm={:?}", kplan.arm());
        }
    }

    #[test]
    fn standard_strategy_is_rejected() {
        let err = MixedRadixPlan::<f64>::new(48, Strategy::Standard, Direction::Forward)
            .unwrap_err();
        assert!(matches!(err, FftError::UnsupportedStrategy { .. }));
    }

    #[test]
    fn forced_simd_errors_for_soft_floats() {
        let res = MixedRadixPlan::<F16>::with_kernel(
            48, Strategy::DualSelect, Direction::Forward, Kernel::Simd,
        );
        if kernel_env_override() == Some(Kernel::Scalar) {
            // The CI fallback run (FMAFFT_KERNEL=portable) caps every
            // request before SIMD support is ever consulted.
            assert_eq!(res.unwrap().arm(), Arm::Portable);
        } else {
            assert!(matches!(res.unwrap_err(), FftError::Unsupported(_)));
        }
        // Auto quietly takes the portable arm instead.
        let plan = MixedRadixPlan::<F16>::new(48, Strategy::DualSelect, Direction::Forward)
            .unwrap();
        assert_eq!(plan.arm(), Arm::Portable);
    }

    #[test]
    fn soft_floats_transform_on_the_portable_arm() {
        let n = 96;
        let (re, im) = random_signal(n, 11);
        let (wr, wi) = dft::naive_dft(&re, &im, false);
        let p16 =
            MixedRadixPlan::<F16>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let (gr, gi) = run(&p16, &re, &im);
        assert!(rel_l2(&gr, &gi, &wr, &wi) < 0.05, "f16 err");
        let pbf =
            MixedRadixPlan::<Bf16>::new(n, Strategy::DualSelect, Direction::Forward).unwrap();
        let (gr, gi) = run(&pbf, &re, &im);
        assert!(rel_l2(&gr, &gi, &wr, &wi) < 0.2, "bf16 err");
    }

    #[test]
    fn theorem_one_bound_survives_the_kernel() {
        for n in [6usize, 48, 96, 1536] {
            let plan =
                MixedRadixPlan::<f64>::new(n, Strategy::DualSelect, Direction::Forward)
                    .unwrap();
            assert!(plan.max_ratio() <= 1.0 + 1e-15, "n={n}");
            assert!(plan.table_bytes() > 0);
        }
        let lf = MixedRadixPlan::<f64>::new(48, Strategy::LinzerFeig, Direction::Forward)
            .unwrap();
        assert!(lf.max_ratio() > 1e6, "clamped LF table must stay honest");
    }

    #[test]
    fn scratch_stops_allocating_after_warmup() {
        use crate::fft::api::batch::FrameArena;
        let plan =
            MixedRadixPlan::<f64>::new(96, Strategy::DualSelect, Direction::Forward).unwrap();
        let mut scratch = Scratch::new();
        let mut arena = FrameArena::<f64>::new(96);
        for _ in 0..4 {
            arena.push_zeroed();
        }
        plan.execute_many(arena.view_mut(), &mut scratch);
        let warm = scratch.misses();
        plan.execute_many(arena.view_mut(), &mut scratch);
        assert_eq!(scratch.misses(), warm, "allocated after warmup");
    }

    #[test]
    fn dispatch_counters_tick_per_frame() {
        let plan =
            MixedRadixPlan::<f64>::new(48, Strategy::DualSelect, Direction::Forward).unwrap();
        let before = super::super::dispatch_counts();
        let mut buf = SplitBuf::<f64>::zeroed(48);
        plan.execute_alloc(&mut buf);
        plan.execute_alloc(&mut buf);
        let after = super::super::dispatch_counts();
        let ticks = (after.scalar + after.simd) - (before.scalar + before.simd);
        assert!(ticks >= 2, "expected >= 2 dispatch ticks, saw {ticks}");
    }
}
