//! `fft::kernel` — the SIMD mixed-radix Autosort engine.
//!
//! The paper's dual-select strategy is a *table* property ("only the
//! precomputed twiddle table changes"), so nothing about it is
//! radix-2-specific or scalar-specific.  This plane takes that
//! seriously in both directions at once:
//!
//! * **Mixed radix** — [`MixedRadixPlan`] runs a Stockham/Autosort
//!   recurrence over radix-2/3/4/8 passes, serving every composite
//!   `n = 2^a · 3^b` directly (48, 96, 1536, ...) instead of taking
//!   the 3–5× Bluestein detour.  Every twiddle multiply, at every
//!   radix, is stored in the paper's bounded-ratio `(m1, m2, t, sel)`
//!   form; `|t| ≤ 1` remains the numerical contract
//!   ([`twiddles::tables_tmax`] is what `analysis::bounds` prices).
//! * **Runtime dispatch** — each plan freezes a dispatch [`Arm`] at
//!   build time: the AVX2/FMA arm ([`simd`]) when the host and element
//!   type support it, the portable scalar arm ([`passes`]) otherwise.
//!   The two arms execute the same per-element operation sequence and
//!   are bit identical (tests/kernel_plane.rs proves it); dispatch is
//!   therefore invisible to every numerical guarantee.
//!
//! Layer map: [`schedule`] factors n into passes, [`twiddles`] builds
//! the per-pass ratio tables, [`butterflies`] holds the scalar
//! radix-3/4/8 micro-kernels, [`passes`]/[`simd`] are the two dispatch
//! arms, and [`plan`] wraps it all in a [`crate::fft::api::Transform`].
//! Routing lives in `fft::api::spec` (composite sizes reach this plane
//! through `Algorithm::Auto`), tuning in `tune::search` (kernel choice
//! is part of the wisdom candidate space), and the dispatch counters
//! below surface through `fft::obs`.

use core::sync::atomic::{AtomicU64, Ordering};

use crate::fft::{FftError, FftResult};

pub mod butterflies;
pub mod passes;
pub mod plan;
pub mod schedule;
pub mod simd;
pub mod twiddles;

pub use plan::MixedRadixPlan;
pub use schedule::{factor23, is_23_smooth, plan_radices, SUPPORTED_RADICES};
pub use simd::simd_available;
pub use twiddles::{build_passes, tables_tmax, PassTables};

/// Which butterfly kernel a plan should use — the tunable axis wisdom
/// records per (n, op, dtype, host).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kernel {
    /// Resolve at plan build: SIMD when the host supports it.
    #[default]
    Auto,
    /// Force the portable scalar arm.
    Scalar,
    /// Require the AVX2/FMA arm; plan construction fails where the
    /// host (or element type) cannot serve it.
    Simd,
}

impl Kernel {
    pub const ALL: [Kernel; 3] = [Kernel::Auto, Kernel::Scalar, Kernel::Simd];

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Auto => "auto",
            Kernel::Scalar => "scalar",
            Kernel::Simd => "simd",
        }
    }
}

impl core::fmt::Display for Kernel {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        f.write_str(self.name())
    }
}

impl core::str::FromStr for Kernel {
    type Err = FftError;
    fn from_str(s: &str) -> FftResult<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(Kernel::Auto),
            "scalar" | "portable" => Ok(Kernel::Scalar),
            "simd" | "vector" => Ok(Kernel::Simd),
            _ => Err(FftError::InvalidArgument(format!(
                "unknown kernel '{s}' (expected auto, scalar or simd)"
            ))),
        }
    }
}

/// The dispatch arm a plan resolved to at build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arm {
    /// Portable scalar loops ([`passes`]) — valid on every target.
    Portable,
    /// AVX2/FMA vector loops ([`simd`]) — x86_64 with runtime-detected
    /// feature support, f32/f64 only.
    Simd,
}

impl Arm {
    pub fn name(&self) -> &'static str {
        match self {
            Arm::Portable => "portable",
            Arm::Simd => "simd",
        }
    }
}

/// Environment override for kernel dispatch, read at plan build time:
/// `scalar`/`portable` caps every plan to the portable arm (the CI
/// fallback run and the dispatch test use this), `simd`/`vector`
/// upgrades `Auto` requests to hard SIMD requests, `auto` and unknown
/// values change nothing.
pub const KERNEL_ENV: &str = "FMAFFT_KERNEL";

/// The parsed [`KERNEL_ENV`] override, if one is set and recognized.
pub fn kernel_env_override() -> Option<Kernel> {
    let v = std::env::var(KERNEL_ENV).ok()?;
    v.parse::<Kernel>().ok()
}

static PORTABLE_DISPATCHES: AtomicU64 = AtomicU64::new(0);
static SIMD_DISPATCHES: AtomicU64 = AtomicU64::new(0);

/// Record one frame executed on `arm` (called by
/// [`MixedRadixPlan::execute_in`]; surfaced via `fft::obs`).
pub(crate) fn note_dispatch(arm: Arm) {
    match arm {
        Arm::Portable => PORTABLE_DISPATCHES.fetch_add(1, Ordering::Relaxed),
        Arm::Simd => SIMD_DISPATCHES.fetch_add(1, Ordering::Relaxed),
    };
}

/// Process-lifetime mixed-radix dispatch counters, by arm.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DispatchCounts {
    /// Frames executed on the portable scalar arm.
    pub scalar: u64,
    /// Frames executed on the AVX2/FMA arm.
    pub simd: u64,
}

impl DispatchCounts {
    pub fn total(&self) -> u64 {
        self.scalar + self.simd
    }
}

/// Snapshot the per-arm dispatch counters (monotonic, process-wide).
pub fn dispatch_counts() -> DispatchCounts {
    DispatchCounts {
        scalar: PORTABLE_DISPATCHES.load(Ordering::Relaxed),
        simd: SIMD_DISPATCHES.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_names_roundtrip() {
        for k in Kernel::ALL {
            assert_eq!(k.name().parse::<Kernel>().unwrap(), k);
            assert_eq!(format!("{k}"), k.name());
        }
        assert_eq!("portable".parse::<Kernel>().unwrap(), Kernel::Scalar);
        assert_eq!("vector".parse::<Kernel>().unwrap(), Kernel::Simd);
        assert!(matches!(
            "avx512".parse::<Kernel>(),
            Err(FftError::InvalidArgument(_))
        ));
    }

    #[test]
    fn dispatch_counters_are_monotonic() {
        let before = dispatch_counts();
        note_dispatch(Arm::Portable);
        note_dispatch(Arm::Simd);
        let after = dispatch_counts();
        assert!(after.scalar >= before.scalar + 1);
        assert!(after.simd >= before.simd + 1);
        assert!(after.total() >= before.total() + 2);
    }

    #[test]
    fn arm_names_are_stable() {
        // These strings are metric labels; changing them breaks
        // dashboards.
        assert_eq!(Arm::Portable.name(), "portable");
        assert_eq!(Arm::Simd.name(), "simd");
    }
}
