//! Radar pulse compression — the paper's motivating application.
//!
//! A matched filter correlates the received signal with the reference
//! chirp in the frequency domain: `y = IFFT(FFT(x) · conj(H))`.  The
//! echo delay appears as a sharp peak; pulse-compression gain is the
//! ratio of the peak to the pre-compression SNR.
//!
//! [`MatchedFilter`] holds its forward/inverse plans (fetched from the
//! shared [`Planner`] at build time) and implements
//! [`Transform`], so the coordinator's workers batch-execute it
//! exactly like a plain FFT.

use std::sync::Arc;

use crate::fft::convolve::pointwise_mul_conj_in;
use crate::fft::{Direction, FftError, FftResult, Planner, Scratch, Strategy, Transform};
use crate::precision::{Real, SplitBuf};

/// A pulse-compression processor with a precomputed reference spectrum.
#[derive(Debug)]
pub struct MatchedFilter<T: Real> {
    pub n: usize,
    pub strategy: Strategy,
    /// FFT of the zero-padded reference pulse (working precision).
    spectrum: SplitBuf<T>,
    fwd: Arc<dyn Transform<T>>,
    inv: Arc<dyn Transform<T>>,
}

impl<T: Real> MatchedFilter<T> {
    /// Build from a reference pulse (length <= n; zero-padded).
    pub fn new(
        planner: &Planner<T>,
        strategy: Strategy,
        n: usize,
        pulse_re: &[f64],
        pulse_im: &[f64],
    ) -> FftResult<Self> {
        if pulse_re.len() > n {
            return Err(FftError::InvalidArgument(format!(
                "pulse ({}) longer than frame ({n})",
                pulse_re.len()
            )));
        }
        let fwd = planner.plan(n, strategy, Direction::Forward)?;
        let inv = planner.plan(n, strategy, Direction::Inverse)?;

        let mut padded_re = vec![0.0; n];
        let mut padded_im = vec![0.0; n];
        padded_re[..pulse_re.len()].copy_from_slice(pulse_re);
        padded_im[..pulse_im.len()].copy_from_slice(pulse_im);

        let mut spectrum = SplitBuf::<T>::from_f64(&padded_re, &padded_im);
        let mut scratch = SplitBuf::zeroed(n);
        fwd.execute(&mut spectrum, &mut scratch);
        Ok(MatchedFilter { n, strategy, spectrum, fwd, inv })
    }

    /// Compress one planar frame in place:
    /// `x ← IFFT(FFT(x)·conj(H))`, with all working buffers drawn
    /// from the pooled `scratch` (the conjugate multiply itself runs
    /// in place — no product buffer).
    pub fn compress_frame(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        assert_eq!(re.len(), self.n, "buffer length != plan size");
        assert_eq!(im.len(), self.n, "buffer length != plan size");
        self.fwd.execute_frame(re, im, scratch);
        pointwise_mul_conj_in(re, im, &self.spectrum.re, &self.spectrum.im);
        self.inv.execute_frame(re, im, scratch);
    }

    /// Compress one frame in place: `x ← IFFT(FFT(x)·conj(H))`.
    /// (Owned-buffer adapter over [`MatchedFilter::compress_frame`].)
    pub fn compress(&self, x: &mut SplitBuf<T>, scratch: &mut SplitBuf<T>) -> FftResult<()> {
        if x.len() != self.n {
            return Err(FftError::LengthMismatch { expected: self.n, got: x.len() });
        }
        let mut pool = Scratch::new();
        pool.put(core::mem::take(scratch));
        self.compress_frame(&mut x.re, &mut x.im, &mut pool);
        *scratch = pool.take(self.n);
        Ok(())
    }
}

impl<T: Real> Transform<T> for MatchedFilter<T> {
    fn len(&self) -> usize {
        self.n
    }
    fn strategy(&self) -> Strategy {
        self.strategy
    }
    fn direction(&self) -> Direction {
        Direction::Forward
    }
    fn execute_frame(&self, re: &mut [T], im: &mut [T], scratch: &mut Scratch<T>) {
        self.compress_frame(re, im, scratch);
    }
}

/// Result of a compression measurement.
#[derive(Clone, Debug)]
pub struct CompressionResult {
    /// Sample index of the compressed peak (echo delay).
    pub peak_index: usize,
    /// Peak magnitude.
    pub peak: f64,
    /// Mean off-peak magnitude (sidelobe + noise floor).
    pub floor: f64,
}

/// Locate the compression peak of a processed frame.
pub fn analyze_peak<T: Real>(x: &SplitBuf<T>, guard: usize) -> CompressionResult {
    let n = x.len();
    let mag: Vec<f64> = (0..n)
        .map(|i| {
            let (r, im) = (x.re[i].to_f64(), x.im[i].to_f64());
            (r * r + im * im).sqrt()
        })
        .collect();
    // NaN-robust argmax (an overflowed fp16 pipeline produces NaNs —
    // treat them as "no detection", not a panic).
    let mut peak_index = 0usize;
    let mut peak = f64::NEG_INFINITY;
    for (i, &m) in mag.iter().enumerate() {
        if m > peak {
            peak = m;
            peak_index = i;
        }
    }
    if !peak.is_finite() {
        peak = 0.0;
    }
    let mut off: f64 = 0.0;
    let mut count = 0usize;
    for (i, &m) in mag.iter().enumerate() {
        let d = (i as isize - peak_index as isize).unsigned_abs();
        if d > guard && (n - d) > guard {
            off += m;
            count += 1;
        }
    }
    CompressionResult { peak_index, peak, floor: off / count.max(1) as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::chirp::default_chirp;
    use crate::signal::noise::{add_into, cwgn, sigma_for_snr_db};
    use crate::util::prng::Pcg32;

    fn echo_frame(n: usize, pulse_len: usize, delay: usize, snr_db: f64, seed: u64)
        -> (Vec<f64>, Vec<f64>) {
        let (cr, ci) = default_chirp(pulse_len);
        let mut re = vec![0.0; n];
        let mut im = vec![0.0; n];
        re[delay..delay + pulse_len].copy_from_slice(&cr);
        im[delay..delay + pulse_len].copy_from_slice(&ci);
        let mut rng = Pcg32::seed(seed);
        let (nr, ni) = cwgn(n, sigma_for_snr_db(snr_db), &mut rng);
        add_into((&mut re, &mut im), (&nr, &ni));
        (re, im)
    }

    #[test]
    fn finds_echo_delay_in_noise() {
        let n = 1024;
        let delay = 300;
        let (re, im) = echo_frame(n, 256, delay, 0.0, 71); // 0 dB SNR
        let planner = Planner::<f64>::new();
        let (cr, ci) = default_chirp(256);
        let mf = MatchedFilter::new(&planner, Strategy::DualSelect, n, &cr, &ci).unwrap();
        let mut x = SplitBuf::from_f64(&re, &im);
        let mut scratch = SplitBuf::zeroed(n);
        mf.compress(&mut x, &mut scratch).unwrap();
        let res = analyze_peak(&x, 8);
        assert_eq!(res.peak_index, delay);
        // Pulse-compression gain: peak well above the floor.
        assert!(res.peak / res.floor > 10.0, "gain {}", res.peak / res.floor);
    }

    #[test]
    fn fp16_dual_select_still_finds_echo() {
        // The paper's point: fp16 + dual-select is usable for radar.
        let n = 1024;
        let delay = 111;
        let (re, im) = echo_frame(n, 256, delay, 10.0, 72);
        // Scale down to fp16-friendly range.
        let re: Vec<f64> = re.iter().map(|x| x * 0.1).collect();
        let im: Vec<f64> = im.iter().map(|x| x * 0.1).collect();
        let planner = Planner::<crate::precision::F16>::new();
        let (cr, ci) = default_chirp(256);
        let mf =
            MatchedFilter::new(&planner, Strategy::DualSelect, n, &cr, &ci).unwrap();
        let mut x = SplitBuf::from_f64(&re, &im);
        let mut scratch = SplitBuf::zeroed(n);
        mf.compress(&mut x, &mut scratch).unwrap();
        let res = analyze_peak(&x, 8);
        assert_eq!(res.peak_index, delay);
    }

    #[test]
    fn rejects_mismatched_lengths() {
        let planner = Planner::<f64>::new();
        let (cr, ci) = default_chirp(64);
        let err = MatchedFilter::new(&planner, Strategy::DualSelect, 32, &cr, &ci).unwrap_err();
        assert!(matches!(err, FftError::InvalidArgument(_)), "{err}");
        assert!(err.to_string().contains("pulse (64) longer than frame (32)"), "{err}");
        let mf = MatchedFilter::new(&planner, Strategy::DualSelect, 128, &cr, &ci).unwrap();
        let mut x = SplitBuf::<f64>::zeroed(64);
        let mut s = SplitBuf::zeroed(64);
        assert_eq!(
            mf.compress(&mut x, &mut s).unwrap_err(),
            FftError::LengthMismatch { expected: 128, got: 64 }
        );
    }

    #[test]
    fn matched_filter_is_a_transform() {
        // The serving plane drives it through the facade.
        let n = 512;
        let delay = 77;
        let (re, im) = echo_frame(n, 128, delay, 5.0, 74);
        let planner = Planner::<f32>::new();
        let (cr, ci) = default_chirp(128);
        let mf = MatchedFilter::new(&planner, Strategy::DualSelect, n, &cr, &ci).unwrap();
        let t: &dyn Transform<f32> = &mf;
        assert_eq!(t.len(), n);
        let mut bufs = vec![SplitBuf::<f32>::from_f64(&re, &im); 3];
        let mut scratch = SplitBuf::zeroed(n);
        t.execute_batch(&mut bufs, &mut scratch);
        for b in &bufs {
            assert_eq!(analyze_peak(b, 8).peak_index, delay);
        }
    }

    #[test]
    fn compression_gain_scales_with_pulse_length() {
        // Longer pulse -> more compression gain (≈ pulse length).
        let n = 2048;
        let planner = Planner::<f64>::new();
        let mut gains = Vec::new();
        for pulse_len in [64usize, 256] {
            let (re, im) = echo_frame(n, pulse_len, 500, -5.0, 73);
            let (cr, ci) = default_chirp(pulse_len);
            let mf = MatchedFilter::new(&planner, Strategy::DualSelect, n, &cr, &ci).unwrap();
            let mut x = SplitBuf::from_f64(&re, &im);
            let mut scratch = SplitBuf::zeroed(n);
            mf.compress(&mut x, &mut scratch).unwrap();
            let res = analyze_peak(&x, pulse_len);
            gains.push(res.peak / res.floor);
        }
        assert!(gains[1] > gains[0], "gains {gains:?}");
    }
}
