//! Short-time Fourier transform / spectrogram on top of the plan API.

use crate::fft::{Direction, FftError, FftResult, Planner, Strategy, Transform};
use crate::precision::{Real, SplitBuf};

use super::window::Window;

/// STFT configuration.
#[derive(Clone, Copy, Debug)]
pub struct StftConfig {
    /// FFT size per column (power of two).
    pub frame: usize,
    /// Hop between consecutive frames.
    pub hop: usize,
    pub window: Window,
    pub strategy: Strategy,
}

/// A spectrogram: `cols` columns of `frame` power values each
/// (row-major, column-contiguous).
#[derive(Clone, Debug)]
pub struct Spectrogram {
    pub frame: usize,
    pub cols: usize,
    /// |X|² per (col, bin), length `cols * frame`.
    pub power: Vec<f64>,
}

impl Spectrogram {
    pub fn at(&self, col: usize, bin: usize) -> f64 {
        self.power[col * self.frame + bin]
    }

    /// Bin with maximum power in a column (see [`peak_bin`] for the
    /// NaN semantics).
    pub fn peak_bin(&self, col: usize) -> usize {
        peak_bin(&self.power[col * self.frame..(col + 1) * self.frame])
    }
}

/// Bin with maximum power in one spectrum column, NaN-safe: ordering
/// is IEEE `total_cmp`, so a NaN power (possible when a low-precision
/// transform overflows) deterministically wins — NaN sorts above +inf
/// in the total order — instead of panicking the way
/// `partial_cmp(..).unwrap()` used to.  Returns 0 for an empty slice.
pub fn peak_bin(power: &[f64]) -> usize {
    power
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Compute the spectrogram of a complex signal.
pub fn stft<T: Real>(
    planner: &Planner<T>,
    cfg: &StftConfig,
    re: &[f64],
    im: &[f64],
) -> FftResult<Spectrogram> {
    if cfg.hop == 0 {
        return Err(FftError::InvalidArgument("hop must be positive".into()));
    }
    let n = re.len();
    if n < cfg.frame {
        return Err(FftError::LengthMismatch { expected: cfg.frame, got: n });
    }
    let plan = planner.plan(cfg.frame, cfg.strategy, Direction::Forward)?;
    let win = cfg.window.sample(cfg.frame);
    let cols = (n - cfg.frame) / cfg.hop + 1;

    let mut power = Vec::with_capacity(cols * cfg.frame);
    let mut buf = SplitBuf::<T>::zeroed(cfg.frame);
    let mut scratch = SplitBuf::zeroed(cfg.frame);
    for c in 0..cols {
        let off = c * cfg.hop;
        for i in 0..cfg.frame {
            buf.re[i] = T::from_f64(re[off + i] * win[i]);
            buf.im[i] = T::from_f64(im[off + i] * win[i]);
        }
        plan.execute(&mut buf, &mut scratch);
        for i in 0..cfg.frame {
            let (r, im_) = (buf.re[i].to_f64(), buf.im[i].to_f64());
            power.push(r * r + im_ * im_);
        }
    }
    Ok(Spectrogram { frame: cfg.frame, cols, power })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tone(n: usize, f: f64) -> (Vec<f64>, Vec<f64>) {
        let tau = 2.0 * core::f64::consts::PI;
        (
            (0..n).map(|t| (tau * f * t as f64).cos()).collect(),
            (0..n).map(|t| (tau * f * t as f64).sin()).collect(),
        )
    }

    fn cfg(frame: usize, hop: usize) -> StftConfig {
        StftConfig { frame, hop, window: Window::Hann, strategy: Strategy::DualSelect }
    }

    #[test]
    fn stationary_tone_peaks_at_its_bin() {
        let planner = Planner::<f64>::new();
        let (re, im) = tone(2048, 10.0 / 256.0); // bin 10 of a 256 frame
        let sg = stft(&planner, &cfg(256, 128), &re, &im).unwrap();
        for c in 0..sg.cols {
            assert_eq!(sg.peak_bin(c), 10, "col {c}");
        }
    }

    #[test]
    fn chirp_peak_bin_moves_up() {
        let planner = Planner::<f64>::new();
        let (re, im) = super::super::chirp::lfm_chirp(8192, 0.02, 0.40);
        let sg = stft(&planner, &cfg(256, 256), &re, &im).unwrap();
        let first = sg.peak_bin(0);
        let last = sg.peak_bin(sg.cols - 1);
        assert!(last > first + 10, "first {first} last {last}");
    }

    #[test]
    fn column_count() {
        let planner = Planner::<f64>::new();
        let (re, im) = tone(1024, 0.1);
        let sg = stft(&planner, &cfg(256, 128), &re, &im).unwrap();
        assert_eq!(sg.cols, (1024 - 256) / 128 + 1);
        assert_eq!(sg.power.len(), sg.cols * 256);
    }

    #[test]
    fn peak_bin_survives_nan_power() {
        // Regression: a NaN power cell used to panic peak_bin via
        // partial_cmp().unwrap(); under total_cmp it wins the max
        // deterministically (NaN > +inf in the IEEE total order).
        let mut sg = Spectrogram { frame: 4, cols: 2, power: vec![0.0; 8] };
        sg.power[1] = 7.0;
        assert_eq!(sg.peak_bin(0), 1);
        sg.power[6] = f64::NAN;
        assert_eq!(sg.peak_bin(1), 2); // no panic; NaN bin reported
        assert_eq!(sg.peak_bin(0), 1); // clean columns unaffected
    }

    #[test]
    fn errors_on_bad_config() {
        let planner = Planner::<f64>::new();
        let (re, im) = tone(128, 0.1);
        assert!(stft(&planner, &cfg(256, 64), &re, &im).is_err()); // too short
        let mut bad = cfg(64, 0);
        bad.hop = 0;
        assert!(stft(&planner, &bad, &re, &im).is_err());
    }
}
