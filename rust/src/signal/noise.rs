//! Calibrated noise generation for synthetic radar returns and test
//! workloads.

use crate::util::prng::Pcg32;

/// Complex white Gaussian noise with per-component std `sigma`.
pub fn cwgn(n: usize, sigma: f64, rng: &mut Pcg32) -> (Vec<f64>, Vec<f64>) {
    (
        (0..n).map(|_| sigma * rng.gaussian()).collect(),
        (0..n).map(|_| sigma * rng.gaussian()).collect(),
    )
}

/// Add `b` into `a` elementwise.
pub fn add_into(a: (&mut [f64], &mut [f64]), b: (&[f64], &[f64])) {
    for (x, y) in a.0.iter_mut().zip(b.0) {
        *x += y;
    }
    for (x, y) in a.1.iter_mut().zip(b.1) {
        *x += y;
    }
}

/// Signal power (mean |x|²).
pub fn power(re: &[f64], im: &[f64]) -> f64 {
    re.iter().zip(im).map(|(r, i)| r * r + i * i).sum::<f64>() / re.len() as f64
}

/// Noise std for a target SNR (dB) against a unit-power signal.
pub fn sigma_for_snr_db(snr_db: f64) -> f64 {
    // Complex noise power = 2σ²; SNR = 1 / (2σ²).
    (10f64.powf(-snr_db / 10.0) / 2.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cwgn_power_calibrated() {
        let mut rng = Pcg32::seed(61);
        let (re, im) = cwgn(50_000, 0.5, &mut rng);
        // Complex power = 2σ² = 0.5
        assert!((power(&re, &im) - 0.5).abs() < 0.01);
    }

    #[test]
    fn snr_calibration() {
        let sigma = sigma_for_snr_db(10.0);
        let mut rng = Pcg32::seed(62);
        let (re, im) = cwgn(100_000, sigma, &mut rng);
        let snr = 1.0 / power(&re, &im);
        let snr_db = 10.0 * snr.log10();
        assert!((snr_db - 10.0).abs() < 0.2, "snr {snr_db}");
    }

    #[test]
    fn add_into_sums() {
        let mut ar = vec![1.0, 2.0];
        let mut ai = vec![0.0, 0.0];
        add_into((&mut ar, &mut ai), (&[0.5, 0.5], &[1.0, -1.0]));
        assert_eq!(ar, vec![1.5, 2.5]);
        assert_eq!(ai, vec![1.0, -1.0]);
    }
}
