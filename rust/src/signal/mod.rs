//! Signal-processing applications built on the FFT core — the
//! workloads the paper's introduction motivates ("real-time radar and
//! neural network inference").
//!
//! * [`window`] — analysis windows for the STFT
//! * [`chirp`] — LFM radar waveforms
//! * [`noise`] — calibrated noise generators
//! * [`stft`] — short-time Fourier transform / spectrograms
//! * [`pulse`] — radar pulse compression (matched filter)

pub mod chirp;
pub mod noise;
pub mod pulse;
pub mod stft;
pub mod window;
