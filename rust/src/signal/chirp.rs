//! Linear-FM (chirp) waveforms — the canonical radar pulse.
//! Mirrors `python/compile/model.py::lfm_chirp` exactly so the Rust
//! native path and the AOT artifacts agree on the reference pulse.

/// Complex LFM chirp: unit amplitude, instantaneous frequency sweeping
/// `f0 → f1` cycles/sample over `n` samples.
pub fn lfm_chirp(n: usize, f0: f64, f1: f64) -> (Vec<f64>, Vec<f64>) {
    let mut re = Vec::with_capacity(n);
    let mut im = Vec::with_capacity(n);
    for t in 0..n {
        let t = t as f64;
        let phase = 2.0 * core::f64::consts::PI * (f0 * t + 0.5 * (f1 - f0) * t * t / n as f64);
        re.push(phase.cos());
        im.push(phase.sin());
    }
    (re, im)
}

/// The default chirp used by the matched-filter artifacts
/// (`f0 = 0.05`, `f1 = 0.45` — matches `model.lfm_chirp` defaults).
pub fn default_chirp(n: usize) -> (Vec<f64>, Vec<f64>) {
    lfm_chirp(n, 0.05, 0.45)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_amplitude() {
        let (re, im) = lfm_chirp(256, 0.05, 0.45);
        for i in 0..256 {
            let mag = (re[i] * re[i] + im[i] * im[i]).sqrt();
            assert!((mag - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn starts_at_phase_zero() {
        let (re, im) = lfm_chirp(64, 0.1, 0.4);
        assert!((re[0] - 1.0).abs() < 1e-12);
        assert!(im[0].abs() < 1e-12);
    }

    #[test]
    fn instantaneous_frequency_sweeps_up() {
        // Phase difference between consecutive samples grows along an
        // up-chirp.
        let (re, im) = lfm_chirp(1024, 0.01, 0.30);
        let phase = |i: usize| im[i].atan2(re[i]);
        let dp_early = (phase(11) - phase(10)).rem_euclid(2.0 * core::f64::consts::PI);
        let dp_late = (phase(901) - phase(900)).rem_euclid(2.0 * core::f64::consts::PI);
        assert!(dp_late > dp_early, "{dp_early} {dp_late}");
    }

    #[test]
    fn matches_python_reference_values() {
        // Spot values computed with the python model (same formula).
        let (re, _) = lfm_chirp(1024, 0.05, 0.45);
        let t: f64 = 100.0;
        let phase = 2.0 * core::f64::consts::PI * (0.05 * t + 0.5 * 0.4 * t * t / 1024.0);
        assert!((re[100] - phase.cos()).abs() < 1e-12);
    }
}
