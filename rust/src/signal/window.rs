//! Analysis windows (f64; rounded into working precision by callers).
//!
//! ## Periodic vs symmetric sampling
//!
//! [`Window::sample`] produces the **periodic** (DFT-even) form:
//! `w[i] = f(i / n)`, i.e. the window is one period of an n-periodic
//! function and the right endpoint `w[n]` (= `w[0]`) is *not* stored.
//! This is the correct form for spectral analysis and for
//! constant-overlap-add (COLA) reconstruction — periodic Hann at
//! `hop = n/2` sums to exactly 1 everywhere.  The *symmetric* form
//! (`f(i / (n-1))`, endpoints both stored — what filter-design texts
//! tabulate) is **not** COLA at `hop = n/2` and is deliberately not
//! provided here; resample a symmetric window of length `n+1` and drop
//! the last sample if you ever need one.
//!
//! [`Window::cola_error`] measures the COLA defect for any
//! (window, hop) pair, so overlap-add synthesis code can assert its
//! configuration reconstructs before trusting it.

use crate::fft::{FftError, FftResult};

/// Window function families.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Window {
    Rect,
    Hann,
    Hamming,
    Blackman,
}

impl Window {
    /// Every supported window, in wire-tag order (see `PROTOCOL.md`).
    pub const ALL: [Window; 4] = [Window::Rect, Window::Hann, Window::Hamming, Window::Blackman];

    /// Short name used by the CLI and the stream wire format.
    pub fn name(self) -> &'static str {
        match self {
            Window::Rect => "rect",
            Window::Hann => "hann",
            Window::Hamming => "hamming",
            Window::Blackman => "blackman",
        }
    }

    /// Sample the window at length `n` (periodic form, for STFT use —
    /// see the module docs for periodic vs symmetric).
    pub fn sample(self, n: usize) -> Vec<f64> {
        let tau = 2.0 * core::f64::consts::PI;
        (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                match self {
                    Window::Rect => 1.0,
                    Window::Hann => 0.5 - 0.5 * (tau * x).cos(),
                    Window::Hamming => 0.54 - 0.46 * (tau * x).cos(),
                    Window::Blackman => {
                        0.42 - 0.5 * (tau * x).cos() + 0.08 * (2.0 * tau * x).cos()
                    }
                }
            })
            .collect()
    }

    /// Coherent gain (mean of the window) — used to normalize spectra.
    pub fn coherent_gain(self, n: usize) -> f64 {
        self.sample(n).iter().sum::<f64>() / n as f64
    }

    /// Constant-overlap-add defect of this window at length `n` and
    /// hop `hop`: the overlap sum `s(j) = Σ_m w[j − m·hop]` is
    /// `hop`-periodic in steady state, and a COLA pair reconstructs
    /// iff `s` is constant.  Returned is the **relative** deviation
    /// `(max s − min s) / mean s` — 0 for a perfect COLA pair (within
    /// f64 roundoff), e.g. periodic Hann at `hop = n/2`; order-1 for a
    /// non-reconstructing pair.  Overlap-add synthesis divides by `s`,
    /// so this is exactly the ripple it must correct.
    pub fn cola_error(self, n: usize, hop: usize) -> f64 {
        assert!(n > 0 && hop > 0, "window length and hop must be positive");
        let w = self.sample(n);
        // Steady-state overlap sum over one hop period: for j in
        // [0, hop), every window copy indexed i ≡ j (mod hop) with
        // 0 <= i < n contributes w[i].
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut total = 0.0;
        for j in 0..hop {
            let mut s = 0.0;
            let mut i = j;
            while i < n {
                s += w[i];
                i += hop;
            }
            lo = lo.min(s);
            hi = hi.max(s);
            total += s;
        }
        let mean = total / hop as f64;
        if mean == 0.0 {
            return f64::INFINITY;
        }
        (hi - lo) / mean
    }
}

impl core::fmt::Display for Window {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        f.write_str(self.name())
    }
}

impl core::str::FromStr for Window {
    type Err = FftError;
    fn from_str(s: &str) -> FftResult<Self> {
        match s {
            "rect" | "boxcar" => Ok(Window::Rect),
            "hann" | "hanning" => Ok(Window::Hann),
            "hamming" => Ok(Window::Hamming),
            "blackman" => Ok(Window::Blackman),
            other => Err(FftError::InvalidArgument(format!(
                "unknown window {other:?} (expected rect|hann|hamming|blackman)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_is_ones() {
        assert!(Window::Rect.sample(16).iter().all(|&w| w == 1.0));
    }

    #[test]
    fn hann_endpoints_and_peak() {
        let w = Window::Hann.sample(64);
        assert!(w[0].abs() < 1e-12);
        assert!((w[32] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_windows_bounded_01() {
        for win in Window::ALL {
            for &v in &win.sample(128) {
                assert!((-1e-12..=1.0 + 1e-12).contains(&v), "{win:?} {v}");
            }
        }
    }

    #[test]
    fn coherent_gains() {
        assert!((Window::Rect.coherent_gain(64) - 1.0).abs() < 1e-12);
        assert!((Window::Hann.coherent_gain(64) - 0.5).abs() < 1e-12);
        assert!((Window::Hamming.coherent_gain(64) - 0.54).abs() < 1e-12);
    }

    #[test]
    fn names_parse_and_display() {
        for w in Window::ALL {
            assert_eq!(w.name().parse::<Window>().unwrap(), w);
            assert_eq!(w.to_string(), w.name());
        }
        assert_eq!("hanning".parse::<Window>().unwrap(), Window::Hann);
        assert!("kaiser".parse::<Window>().is_err());
    }

    #[test]
    fn periodic_hann_is_cola_at_half_frame() {
        // The invariant overlap-add reconstruction (and future
        // synthesis) relies on: periodic Hann @ hop = n/2 sums to a
        // constant — this is exactly why sample() is periodic, not
        // symmetric (the symmetric form fails this by ~1/n).
        for n in [64usize, 128, 256, 1024] {
            let err = Window::Hann.cola_error(n, n / 2);
            assert!(err < 1e-12, "n={n}: hann@n/2 cola error {err}");
            // hop = n/4 is COLA for Hann too.
            assert!(Window::Hann.cola_error(n, n / 4) < 1e-12);
        }
        // Rect at any exact divisor hop is trivially COLA.
        assert!(Window::Rect.cola_error(64, 16) < 1e-15);
    }

    #[test]
    fn non_cola_pairs_report_large_defect() {
        // Hann with a 3/4-frame hop does not reconstruct.
        assert!(Window::Hann.cola_error(64, 48) > 0.1);
        // Blackman at half frame is close to, but not exactly, COLA.
        let b = Window::Blackman.cola_error(256, 128);
        assert!(b > 1e-6, "blackman@n/2 should have visible ripple, got {b}");
        // Symmetric-vs-periodic spot check: a symmetric Hann (endpoints
        // duplicated) at hop n/2 would NOT be COLA; emulate by
        // resampling and confirm the periodic form is what saves us.
        let n = 64;
        let tau = 2.0 * core::f64::consts::PI;
        let sym: Vec<f64> = (0..n)
            .map(|i| 0.5 - 0.5 * (tau * i as f64 / (n - 1) as f64).cos())
            .collect();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for j in 0..n / 2 {
            let s = sym[j] + sym[j + n / 2];
            lo = lo.min(s);
            hi = hi.max(s);
        }
        assert!((hi - lo) / 1.0 > 1e-3, "symmetric hann must show ripple");
    }

    #[test]
    #[should_panic(expected = "must be positive")]
    fn cola_error_rejects_zero_hop() {
        let _ = Window::Hann.cola_error(64, 0);
    }
}
