//! Analysis windows (f64; rounded into working precision by callers).

/// Window function families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Window {
    Rect,
    Hann,
    Hamming,
    Blackman,
}

impl Window {
    /// Sample the window at length `n` (periodic form, for STFT use).
    pub fn sample(self, n: usize) -> Vec<f64> {
        let tau = 2.0 * core::f64::consts::PI;
        (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                match self {
                    Window::Rect => 1.0,
                    Window::Hann => 0.5 - 0.5 * (tau * x).cos(),
                    Window::Hamming => 0.54 - 0.46 * (tau * x).cos(),
                    Window::Blackman => {
                        0.42 - 0.5 * (tau * x).cos() + 0.08 * (2.0 * tau * x).cos()
                    }
                }
            })
            .collect()
    }

    /// Coherent gain (mean of the window) — used to normalize spectra.
    pub fn coherent_gain(self, n: usize) -> f64 {
        self.sample(n).iter().sum::<f64>() / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_is_ones() {
        assert!(Window::Rect.sample(16).iter().all(|&w| w == 1.0));
    }

    #[test]
    fn hann_endpoints_and_peak() {
        let w = Window::Hann.sample(64);
        assert!(w[0].abs() < 1e-12);
        assert!((w[32] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_windows_bounded_01() {
        for win in [Window::Rect, Window::Hann, Window::Hamming, Window::Blackman] {
            for &v in &win.sample(128) {
                assert!((-1e-12..=1.0 + 1e-12).contains(&v), "{win:?} {v}");
            }
        }
    }

    #[test]
    fn coherent_gains() {
        assert!((Window::Rect.coherent_gain(64) - 1.0).abs() < 1e-12);
        assert!((Window::Hann.coherent_gain(64) - 0.5).abs() < 1e-12);
        assert!((Window::Hamming.coherent_gain(64) - 0.54).abs() < 1e-12);
    }
}
