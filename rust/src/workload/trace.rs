//! Request-arrival traces for the serving benches: Poisson arrivals
//! (open-loop) and closed-loop bursts.

use crate::util::prng::Pcg32;

/// Trace configuration.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate, requests/second.
    pub rate: f64,
    /// Number of requests.
    pub count: usize,
}

/// A generated arrival trace: monotone arrival offsets in seconds.
#[derive(Clone, Debug)]
pub struct ArrivalTrace {
    pub arrivals: Vec<f64>,
}

impl ArrivalTrace {
    /// Open-loop Poisson arrivals.
    pub fn poisson(cfg: TraceConfig, seed: u64) -> Self {
        let mut rng = Pcg32::seed(seed);
        let mut t = 0.0;
        let arrivals = (0..cfg.count)
            .map(|_| {
                t += rng.exponential(cfg.rate);
                t
            })
            .collect();
        ArrivalTrace { arrivals }
    }

    /// Bursty arrivals: `burst` back-to-back requests per burst, bursts
    /// Poisson at `rate / burst`.
    pub fn bursty(cfg: TraceConfig, burst: usize, seed: u64) -> Self {
        let mut rng = Pcg32::seed(seed);
        let burst_rate = cfg.rate / burst.max(1) as f64;
        let mut arrivals = Vec::with_capacity(cfg.count);
        let mut t = 0.0;
        while arrivals.len() < cfg.count {
            t += rng.exponential(burst_rate);
            for _ in 0..burst.min(cfg.count - arrivals.len()) {
                arrivals.push(t);
            }
        }
        ArrivalTrace { arrivals }
    }

    pub fn duration(&self) -> f64 {
        self.arrivals.last().copied().unwrap_or(0.0)
    }

    /// Mean offered rate over the trace.
    pub fn offered_rate(&self) -> f64 {
        if self.arrivals.is_empty() {
            return 0.0;
        }
        self.arrivals.len() as f64 / self.duration().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_calibrated() {
        let t = ArrivalTrace::poisson(TraceConfig { rate: 1000.0, count: 20_000 }, 81);
        assert!((t.offered_rate() - 1000.0).abs() / 1000.0 < 0.05);
        // Monotone arrivals.
        assert!(t.arrivals.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn bursty_preserves_rate_and_groups() {
        let t = ArrivalTrace::bursty(TraceConfig { rate: 1000.0, count: 10_000 }, 8, 82);
        assert_eq!(t.arrivals.len(), 10_000);
        assert!((t.offered_rate() - 1000.0).abs() / 1000.0 < 0.10);
        // Bursts: many consecutive identical timestamps.
        let dup = t.arrivals.windows(2).filter(|w| w[0] == w[1]).count();
        assert!(dup > 8_000);
    }

    #[test]
    fn empty_trace_degenerate() {
        let t = ArrivalTrace::poisson(TraceConfig { rate: 10.0, count: 0 }, 83);
        assert_eq!(t.offered_rate(), 0.0);
        assert_eq!(t.duration(), 0.0);
    }
}
