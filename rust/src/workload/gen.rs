//! Signal-frame generators: the payloads benches and the serving demo
//! push through the FFT pipeline.

use crate::signal::chirp::default_chirp;
use crate::signal::noise::{add_into, cwgn, sigma_for_snr_db};
use crate::util::prng::Pcg32;

/// Kinds of synthetic frames.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SignalKind {
    /// Complex white Gaussian noise (unit power).
    Noise,
    /// Single tone at a random bin.
    Tone,
    /// Radar return: delayed chirp echo + noise at a given SNR (dB).
    RadarReturn { pulse_len: usize, snr_db: f64 },
    /// Uniform random in [-1, 1] (the error-measurement workload).
    Uniform,
}

/// Deterministic workload generator.
pub struct WorkloadGen {
    pub n: usize,
    rng: Pcg32,
}

impl WorkloadGen {
    pub fn new(n: usize, seed: u64) -> Self {
        WorkloadGen { n, rng: Pcg32::seed(seed) }
    }

    /// Generate one frame; for radar returns also returns the true
    /// echo delay (for verification).
    pub fn frame(&mut self, kind: SignalKind) -> Frame {
        let n = self.n;
        match kind {
            SignalKind::Noise => {
                let (re, im) = cwgn(n, core::f64::consts::FRAC_1_SQRT_2, &mut self.rng);
                Frame { re, im, truth: None }
            }
            SignalKind::Uniform => Frame {
                re: (0..n).map(|_| self.rng.range(-1.0, 1.0)).collect(),
                im: (0..n).map(|_| self.rng.range(-1.0, 1.0)).collect(),
                truth: None,
            },
            SignalKind::Tone => {
                let bin = self.rng.below(n);
                let tau = 2.0 * core::f64::consts::PI;
                let re = (0..n)
                    .map(|t| (tau * (bin * t) as f64 / n as f64).cos())
                    .collect();
                let im = (0..n)
                    .map(|t| (tau * (bin * t) as f64 / n as f64).sin())
                    .collect();
                Frame { re, im, truth: Some(bin) }
            }
            SignalKind::RadarReturn { pulse_len, snr_db } => {
                assert!(pulse_len <= n);
                let delay = self.rng.below(n - pulse_len);
                let (cr, ci) = default_chirp(pulse_len);
                let mut re = vec![0.0; n];
                let mut im = vec![0.0; n];
                re[delay..delay + pulse_len].copy_from_slice(&cr);
                im[delay..delay + pulse_len].copy_from_slice(&ci);
                let (nr, ni) = cwgn(n, sigma_for_snr_db(snr_db), &mut self.rng);
                add_into((&mut re, &mut im), (&nr, &ni));
                Frame { re, im, truth: Some(delay) }
            }
        }
    }

    /// Generate a batch of frames.
    pub fn batch(&mut self, kind: SignalKind, count: usize) -> Vec<Frame> {
        (0..count).map(|_| self.frame(kind)).collect()
    }
}

/// One generated frame with optional ground truth (tone bin or echo
/// delay).
#[derive(Clone, Debug)]
pub struct Frame {
    pub re: Vec<f64>,
    pub im: Vec<f64>,
    pub truth: Option<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = WorkloadGen::new(64, 9);
        let mut b = WorkloadGen::new(64, 9);
        let fa = a.frame(SignalKind::Noise);
        let fb = b.frame(SignalKind::Noise);
        assert_eq!(fa.re, fb.re);
    }

    #[test]
    fn radar_return_has_truth_in_range() {
        let mut g = WorkloadGen::new(1024, 10);
        for _ in 0..32 {
            let f = g.frame(SignalKind::RadarReturn { pulse_len: 256, snr_db: 0.0 });
            let d = f.truth.unwrap();
            assert!(d + 256 <= 1024);
            assert_eq!(f.re.len(), 1024);
        }
    }

    #[test]
    fn tone_truth_matches_spectrum_peak() {
        let mut g = WorkloadGen::new(128, 11);
        let f = g.frame(SignalKind::Tone);
        let bin = f.truth.unwrap();
        let (wr, wi) = crate::dft::naive_dft(&f.re, &f.im, false);
        let peak = (0..128)
            .max_by(|&a, &b| {
                (wr[a] * wr[a] + wi[a] * wi[a])
                    .partial_cmp(&(wr[b] * wr[b] + wi[b] * wi[b]))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(peak, bin);
    }

    #[test]
    fn batch_size() {
        let mut g = WorkloadGen::new(32, 12);
        assert_eq!(g.batch(SignalKind::Uniform, 7).len(), 7);
    }
}
