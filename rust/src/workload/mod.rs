//! Synthetic workload generation for benches and the serving demo:
//! signal frames (what requests carry) and request arrival traces
//! (when they arrive).

pub mod gen;
pub mod trace;

pub use gen::{SignalKind, WorkloadGen};
pub use trace::{ArrivalTrace, TraceConfig};
