//! # fmafft — Dual-Select FMA Butterfly FFT framework
//!
//! Reproduction of *"Dual-Select FMA Butterfly for FFT: Eliminating
//! Twiddle Factor Singularities with Bounded Precomputed Ratios"*
//! (M. A. Bergach, CS.PF 2026).
//!
//! The library has three planes, all fronted by one facade:
//!
//! * **Public API** ([`fft::api`]) — the typed [`fft::FftError`], the
//!   [`fft::Transform`] trait (one execute shape for every transform
//!   kind), the [`fft::PlanSpec`] builder, the generalized
//!   [`fft::Planner`] cache, the zero-copy buffer layer
//!   ([`fft::FrameArena`] batch storage, [`fft::FrameBatchMut`]
//!   strided views, pooled [`fft::Scratch`]), and the dtype layer
//!   ([`fft::DType`], dtype-erased [`fft::AnyTransform`] /
//!   [`fft::AnyArena`] / [`fft::AnyPlanner`]) that picks the working
//!   precision at run time.  Start here:
//!   `PlanSpec::new(n).strategy(Strategy::DualSelect).build::<f32>()?`,
//!   then `transform.execute_many(arena.view_mut(), &mut scratch)`;
//!   or `.dtype(DType::F16).build_any()?` for runtime precision.
//! * **Native FFT core** ([`fft`], [`precision`], [`analysis`]) — a
//!   generic-precision radix-2/4 Stockham FFT implementing all four
//!   butterfly strategies the paper compares (standard 10-op,
//!   Linzer–Feig ÷sin, cosine ÷cos, and the paper's dual-select), over
//!   `f64`/`f32` hardware floats and bit-exact software
//!   [`precision::F16`]/[`precision::Bf16`], plus DIT, Bluestein and
//!   real-input (r2c/c2r) organizations.  This is the measurement
//!   instrument for the paper's Tables I–II.
//! * **Serving plane** ([`runtime`], [`coordinator`]) — a
//!   dynamic-batching request coordinator in the style of vLLM's
//!   router, whose workers drive `dyn Transform` batches; the PJRT
//!   artifact runtime is stubbed offline (see [`runtime`]).
//! * **Network plane** ([`net`]) — `fftd`: a zero-dependency TCP
//!   serving layer over the coordinator ([`net::wire`] frame codec,
//!   [`net::FftdServer`], [`net::FftClient`]), so remote callers get
//!   the same dtype + a-priori-bound metadata as in-process ones.
//!   See `PROTOCOL.md` for the wire format.
//! * **Kernel plane** ([`kernel`]) — the SIMD mixed-radix Autosort
//!   engine: [`kernel::MixedRadixPlan`] executes radix-2/3/4/8
//!   Stockham passes over composite `n = 2^a·3^b` (48, 96, 1536 no
//!   longer take the Bluestein detour), with runtime AVX2/FMA
//!   dispatch and a portable fallback that is *bit identical* to the
//!   vector arm.  Twiddles stay in the paper's bounded-ratio
//!   dual-select form at every radix, so `|t| ≤ 1` and the a-priori
//!   bounds survive vectorization unchanged; kernel choice
//!   (auto/scalar/simd) is a [`tune`] search axis and per-arm dispatch
//!   counts surface through [`obs`].
//! * **Fixed-point plane** ([`fixed`]) — a quantized Q15/Q31 integer
//!   FFT with per-frame block-floating-point scaling
//!   ([`fixed::FixedPlan`], [`fixed::FixedArena`]).  Dual-select is
//!   the only strategy whose precomputed ratios satisfy |ratio| ≤ 1,
//!   i.e. the only one *representable* in a signed Q-format —
//!   Linzer–Feig and cosine tables are rejected with a typed error
//!   instead of being clamped.  Every result carries an a-priori
//!   quantization-noise bound ([`analysis::bounds`] fixed-point
//!   model), served end-to-end as `DType::I16`/`DType::I32`.
//! * **Streaming plane** ([`stream`]) — stateful DSP sessions over
//!   continuous signals: overlap-save FIR filtering
//!   ([`stream::OlsFilter`]), streaming STFT ([`stream::StftStream`]),
//!   and the [`stream::SessionRegistry`] session layer whose responses
//!   carry a *running* cumulative a-priori error bound (eq. (11)
//!   applied to serving).  Served remotely via the wire protocol's
//!   `STREAM_*` ops (introduced in v2).
//! * **Graph plane** ([`graph`]) — composable DSP pipeline graphs:
//!   one ingest stream fans through a validated DAG of
//!   [`graph::GraphNode`] stages (window, FFT, overlap-save, STFT,
//!   matched filter, detrend, magnitude, decimate, summary) into named
//!   sink topics; any number of subscribers attach per topic with
//!   `Arc`-shared zero-copy fan-out and per-subscriber lag-drop
//!   backpressure, and every published frame carries the composed
//!   running bound along its source→sink path.  Served remotely via
//!   the wire protocol's `GRAPH_*` ops (introduced in v4).
//! * **Observability plane** ([`obs`]) — makes the running daemon
//!   watchable: per-stage request tracing (admitted → batched →
//!   dequeued → executed → reply-written) aggregated into log-bucketed
//!   stage histograms with a lock-free span ring and worst-K
//!   slow-request exemplars, numerical-health telemetry (sampled
//!   bound-tightness ratios per dtype × strategy, stored-`|t|max`
//!   high-waters, a `bound_violations` counter that must stay 0), and
//!   a served stats surface: the wire protocol's `STATS` op (v6),
//!   Prometheus text exposition via `fft stats --addr`, and
//!   `serve --stats-every` log lines.  Alloc-free on the hot path.
//! * **Autotuning plane** ([`tune`]) — the measured answer to "which
//!   plan?": a deterministic measurement harness, a candidate search
//!   over the existing plan space, and persisted host-fingerprinted
//!   wisdom ([`tune::Wisdom`]) that `fftd --wisdom` loads at boot.
//!   Requests carrying [`fft::StrategyChoice::Auto`] resolve through
//!   it; stream/graph overlap-save opens consult it for FFT block
//!   lengths.  Selection only — results stay bit-identical to the
//!   explicit plans.
//! * **Applications** ([`signal`], [`workload`]) — the radar pulse
//!   compression and spectrogram pipelines the paper motivates, used by
//!   the examples and benches.
//!
//! See `DESIGN.md` (repo root) for the facade diagram, the error
//! taxonomy, migration notes from the pre-facade API, and the
//! experiment index mapping paper tables to benches.

pub mod analysis;
pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod dft;
pub mod fft;
pub mod fixed;
pub mod graph;
pub mod kernel;
pub mod net;
pub mod obs;
pub mod precision;
pub mod runtime;
pub mod signal;
pub mod stream;
pub mod tune;
pub mod util;
pub mod workload;
