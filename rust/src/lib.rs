//! # fmafft — Dual-Select FMA Butterfly FFT framework
//!
//! Reproduction of *"Dual-Select FMA Butterfly for FFT: Eliminating
//! Twiddle Factor Singularities with Bounded Precomputed Ratios"*
//! (M. A. Bergach, CS.PF 2026).
//!
//! The library has three planes:
//!
//! * **Native FFT core** ([`fft`], [`precision`], [`analysis`]) — a
//!   generic-precision radix-2/4 Stockham FFT implementing all four
//!   butterfly strategies the paper compares (standard 10-op,
//!   Linzer–Feig ÷sin, cosine ÷cos, and the paper's dual-select), over
//!   `f64`/`f32` hardware floats and bit-exact software
//!   [`precision::F16`]/[`precision::Bf16`].  This is the measurement
//!   instrument for the paper's Tables I–II.
//! * **Serving plane** ([`runtime`], [`coordinator`]) — a PJRT CPU
//!   client that loads the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`, built once by `make artifacts`; Python is
//!   never on the request path) plus a dynamic-batching request
//!   coordinator in the style of vLLM's router.
//! * **Applications** ([`signal`], [`workload`]) — the radar pulse
//!   compression and spectrogram pipelines the paper motivates, used by
//!   the examples and benches.
//!
//! See `DESIGN.md` for the experiment index mapping every paper table
//! to its regenerating bench, and `EXPERIMENTS.md` for measured-vs-paper
//! results.

pub mod analysis;
pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod dft;
pub mod fft;
pub mod precision;
pub mod runtime;
pub mod signal;
pub mod util;
pub mod workload;
