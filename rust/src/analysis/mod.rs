//! Error analysis: the paper's §IV bounds and §V measurements.
//!
//! * [`ratio`] — precomputed-ratio statistics over the twiddle table
//!   (Table I columns 1-2 + the §V argmax/path-split claims)
//! * [`bounds`] — eq. (10) per-butterfly and eq. (11) cumulative error
//!   bounds (Table I column 3 and Table II)
//! * [`empirical`] — measured forward/roundtrip error of the actual
//!   transforms against the f64 DFT oracle (the §V FP16/FP32 claims)
//! * [`report`] — paper-style table rendering for the CLI and benches

pub mod bounds;
pub mod empirical;
pub mod ratio;
pub mod report;
