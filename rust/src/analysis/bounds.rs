//! The paper's §IV error bounds — eq. (10) per butterfly, eq. (11)
//! cumulative — and the generators for Tables I and II.

use crate::fft::Strategy;
use crate::precision::{Real, F16};

use super::ratio::{ratio_stats, RatioStats};

/// Eq. (10): per-butterfly bound δ < C·|t|·ε·||b||, reported with the
/// paper's normalization (C·||b|| = 1): `tmax · eps`.
pub fn per_butterfly_bound(tmax: f64, eps: f64) -> f64 {
    tmax * eps
}

/// Eq. (11): cumulative relative error over m passes,
/// E ≤ (1 + |t|max·ε)^m − 1  (≈ m·|t|max·ε for small arguments).
///
/// Evaluated as expm1(m·ln1p(t·ε)) so tiny arguments (f64 working
/// precision) do not underflow to 0.
pub fn cumulative_bound(tmax: f64, eps: f64, m: u32) -> f64 {
    (m as f64 * (tmax * eps).ln_1p()).exp_m1()
}

/// One row of Table I.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub strategy: Strategy,
    pub stats: RatioStats,
    /// |t|max as the paper reports it: non-singular max for LF/dual,
    /// the near-singular max for cosine (its ">10^16").
    pub reported_tmax: f64,
    /// Number of true singularities (LF: 1; cosine: 0 with the "near"
    /// caveat; dual: 0).
    pub singularities: usize,
    /// FP16 per-butterfly bound, or +inf when the table diverges in
    /// fp16 (cosine, and LF's stored clamped entry).
    pub fp16_bound: f64,
}

/// Generate Table I for size `n` (paper uses N=1024).
pub fn table1(n: usize) -> Vec<Table1Row> {
    [Strategy::LinzerFeig, Strategy::Cosine, Strategy::DualSelect]
        .into_iter()
        .map(|strategy| {
            let stats = ratio_stats(n, strategy);
            let reported_tmax = match strategy {
                // Paper reports the non-singular max for LF (the W^0
                // singularity is counted in the "Sing." column).
                Strategy::LinzerFeig => stats.max_nonsingular,
                // ... and the near-singular max for cosine (>1e16).
                Strategy::Cosine => stats.max_with_near,
                _ => stats.max_nonsingular,
            };
            let fp16_bound = per_butterfly_bound(reported_tmax, F16::EPSILON);
            Table1Row {
                strategy,
                stats,
                reported_tmax,
                singularities: match strategy {
                    Strategy::LinzerFeig => 1,
                    _ => 0,
                },
                fp16_bound,
            }
        })
        .collect()
}

/// One row of Table II.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub strategy: Strategy,
    pub tmax: f64,
    pub cumulative: f64,
}

/// Generate Table II: cumulative FP16 bound over `m = log2 n` passes,
/// plus the improvement factor (paper: 235× for N=1024).
pub fn table2(n: usize) -> (Vec<Table2Row>, f64) {
    let m = n.trailing_zeros();
    let rows: Vec<Table2Row> = [Strategy::LinzerFeig, Strategy::DualSelect]
        .into_iter()
        .map(|strategy| {
            let tmax = ratio_stats(n, strategy).max_nonsingular;
            Table2Row {
                strategy,
                tmax,
                cumulative: cumulative_bound(tmax, F16::EPSILON, m),
            }
        })
        .collect();
    let improvement = rows[0].cumulative / rows[1].cumulative;
    (rows, improvement)
}

/// Eq. (11) with the butterfly's operation count folded in: the
/// end-to-end a-priori bound the serving plane attaches to responses.
///
/// Each pass produces every output through one 6-FMA ratio butterfly;
/// each FMA rounds once and the ratio path amplifies by at most
/// `(1 + |t|)`, so one pass grows the relative error by at most
/// `(1 + 6·(1 + |t|max)·eps)`.  Over `m` passes:
///
/// ```text
/// E  ≤  (1 + 6·(1 + |t|max)·eps)^m − 1
/// ```
///
/// Unlike [`cumulative_bound`] (the paper's normalized per-ratio
/// form), this covers the whole butterfly arithmetic, so *measured*
/// transform error sits below it — the coordinator integration tests
/// assert exactly that for served f16/bf16 requests.
pub fn serving_bound_from_tmax(tmax: f64, eps: f64, m: u32) -> f64 {
    (m as f64 * (6.0 * (1.0 + tmax) * eps).ln_1p()).exp_m1()
}

/// Per-output rounding-operation count `C_r` for one mixed-radix pass
/// of radix `r` — the constant that replaces the radix-2 butterfly's
/// `6` in the serving-bound recurrence.  `None` for radices the
/// kernel engine has no butterfly for.
///
/// Counts are conservative (each is an upper bound on the roundings
/// any single output accumulates in one pass):
///
/// * radix 2 — the 6-FMA ratio butterfly (the paper's kernel): 6.
/// * radix 3 — one ratio twiddle multiply (3 roundings, `(1+|t|)`
///   amplified) feeding a 3-point DFT whose longest chain is
///   2 adds + 2 FMA: 12 covers twiddle + chain for every output.
/// * radix 4 — one twiddle multiply plus the two-level even/odd
///   add tree (the radix-4 plan's own model): 12.
/// * radix 8 — one twiddle multiply plus two 4-point levels, the
///   `1/√2` rotation (2 roundings) and the final combine: 18.
pub fn radix_pass_ops(radix: usize) -> Option<u32> {
    match radix {
        2 => Some(6),
        3 => Some(12),
        4 => Some(12),
        8 => Some(18),
        _ => None,
    }
}

/// Serving bound for an explicit mixed-radix pass schedule: each
/// radix-`r` pass grows relative error by at most
/// `(1 + C_r·(1 + |t|max)·eps)`, so
///
/// ```text
/// E  ≤  ∏_r (1 + C_r·(1 + |t|max)·eps) − 1
/// ```
///
/// evaluated as `expm1(Σ_r ln1p(C_r·(1+tmax)·eps))` for underflow
/// safety.  For an all-radix-2 schedule this is *exactly*
/// [`serving_bound_from_tmax`] with `m = len(radices)` — the kernel
/// engine's bound degenerates to the classic plan's.  `None` when the
/// schedule contains a radix without an op count.
pub fn serving_bound_schedule(radices: &[usize], tmax: f64, eps: f64) -> Option<f64> {
    let mut acc = 0.0f64;
    for &r in radices {
        let ops = radix_pass_ops(r)? as f64;
        acc += (ops * (1.0 + tmax) * eps).ln_1p();
    }
    Some(acc.exp_m1())
}

/// Serving bound for size `n` given the stored `|t|max` of whatever
/// plan serves it: the classic radix-2 form for powers of two, the
/// canonical mixed-radix schedule's per-radix form for composite
/// `2^a·3^b` sizes, `None` for sizes neither engine serves directly
/// (Bluestein responses carry no a-priori ratio bound).
pub fn serving_bound_for_n(n: usize, tmax: f64, eps: f64) -> Option<f64> {
    if n < 2 {
        return None;
    }
    if n.is_power_of_two() {
        return Some(serving_bound_from_tmax(tmax, eps, n.trailing_zeros()));
    }
    let radices = crate::kernel::plan_radices(n).ok()?;
    serving_bound_schedule(&radices, tmax, eps)
}

/// The serving bound for one transform: `|t|max` is taken from the
/// table as actually *stored* (clamped — for Linzer–Feig/cosine that
/// is the 1e7 clamp entry, which is the paper's point), `eps` is the
/// working dtype's unit roundoff.  Powers of two use the radix-2
/// table; composite `2^a·3^b` sizes use the mixed-radix kernel's
/// tables and per-radix op counts.  `None` when no ratio bound
/// applies (standard butterfly, or a size with another prime factor).
pub fn serving_bound(n: usize, strategy: Strategy, eps: f64) -> Option<f64> {
    if strategy == Strategy::Standard || n < 2 {
        return None;
    }
    if n.is_power_of_two() {
        let m = n.trailing_zeros();
        let tmax = ratio_stats(n, strategy).max_clamped;
        return Some(serving_bound_from_tmax(tmax, eps, m));
    }
    let tmax = crate::kernel::tables_tmax(n, strategy)?;
    serving_bound_for_n(n, tmax, eps)
}

/// Absolute L2 quantization noise injected by fixed-point ingest: one
/// worst-case quantum per real component over an `n`-sample complex
/// frame quantized at block scale `2^scale`,
/// `N₀ = √(2n) · 2^scale`.
///
/// Together with [`fixed_pass_noise`] and [`fixed_relative_bound`]
/// this is the quantized sibling of the eq. (11) chain: where the
/// float bound compounds a *relative* per-pass factor, block
/// floating point injects *absolute* rounding noise per pass whose
/// size tracks the running block exponent, so the chain is run in
/// absolute units and normalized once at the end.
pub fn fixed_ingest_noise(n: usize, scale: i32) -> f64 {
    (2.0 * n as f64).sqrt() * (scale as f64).exp2()
}

/// One radix-2 Stockham pass of the fixed-point noise recurrence:
///
/// ```text
/// N ← √2 · (N_prev + [shifted]·½·√(2n)·2^scale)  +  c·√(2n)·2^scale
/// ```
///
/// * `√2` — the pass's exact L2 gain (each butterfly maps
///   `(a, b) ↦ (a + wb, a − wb)`, which doubles the squared norm), so
///   noise already present is amplified exactly like the signal.
/// * the `shifted` term — when the BFP rule right-shifted the pass's
///   inputs, each component rounds by at most half a (post-shift)
///   quantum *before* the butterfly amplifies it.
/// * `c·√(2n)·2^scale` — fresh per-output rounding: `c = 2` for a
///   ratio pass (one quantum from the two `mul_round` roundings of
///   the 6-op dual-select butterfly, one quantum from the quantized
///   `m1`/`m2`/`t` factors themselves), `c = 0` for a trivial (`W^0`)
///   pass, which is exact integer add/sub.
///
/// `scale` is the block exponent *after* the pass's shift.
pub fn fixed_pass_noise(prev: f64, n: usize, scale: i32, trivial: bool, shifted: bool) -> f64 {
    let q = (2.0 * n as f64).sqrt() * (scale as f64).exp2();
    let carried = prev + if shifted { 0.5 * q } else { 0.0 };
    let injected = if trivial { 0.0 } else { 2.0 * q };
    core::f64::consts::SQRT_2 * carried + injected
}

/// Normalize the accumulated absolute noise after `m` passes into the
/// relative bound the serving plane attaches: the true output of an
/// unnormalized `2^m`-point transform has L2 norm exactly
/// `2^(m/2) · ‖x‖₂` (Parseval), so
///
/// ```text
/// E  ≤  N_m / (2^(m/2) · ‖x‖₂)
/// ```
///
/// The same formula covers the inverse transform: the trailing exact
/// `1/n` fold (a block-exponent subtraction) scales signal and noise
/// alike.  A zero input (‖x‖₂ = 0) quantizes, transforms and
/// dequantizes exactly, so its bound is 0.
pub fn fixed_relative_bound(noise: f64, m: u32, input_l2: f64) -> f64 {
    if input_l2 <= 0.0 {
        return 0.0;
    }
    noise / ((m as f64 * 0.5).exp2() * input_l2)
}

/// Cumulative-bound sweep across precisions for a given strategy pair —
/// the data behind the "advantage is specific to low precision" claim.
pub fn precision_sweep(n: usize) -> Vec<(&'static str, f64, f64, f64)> {
    let m = n.trailing_zeros();
    let lf = ratio_stats(n, Strategy::LinzerFeig).max_nonsingular;
    let dual = ratio_stats(n, Strategy::DualSelect).max_nonsingular;
    [
        ("fp16", F16::EPSILON),
        ("bf16", crate::precision::Bf16::EPSILON),
        ("f32", <f32 as Real>::EPSILON),
        ("f64", <f64 as Real>::EPSILON),
    ]
    .into_iter()
    .map(|(name, eps)| {
        let b_lf = cumulative_bound(lf, eps, m);
        let b_dual = cumulative_bound(dual, eps, m);
        (name, b_lf, b_dual, b_lf / b_dual)
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_n1024() {
        let rows = table1(1024);
        // Row 0: Linzer-Feig — |t|max 163.0, 1 singularity, bound 7.95e-2.
        assert_eq!(rows[0].strategy, Strategy::LinzerFeig);
        assert!((rows[0].reported_tmax - 163.0).abs() < 0.05);
        assert_eq!(rows[0].singularities, 1);
        assert!((rows[0].fp16_bound - 7.95e-2).abs() < 2e-4);
        // Row 1: Cosine — >1e16, divergent fp16 bound.
        assert_eq!(rows[1].strategy, Strategy::Cosine);
        assert!(rows[1].reported_tmax > 1e16);
        assert!(rows[1].fp16_bound > 1e12); // divergent at fp16 scale
        assert_eq!(rows[1].stats.near_singular, 1);
        // Row 2: Dual-select — exactly 1.0, bound = eps = 4.88e-4.
        assert_eq!(rows[2].strategy, Strategy::DualSelect);
        assert!((rows[2].reported_tmax - 1.0).abs() < 1e-12);
        assert_eq!(rows[2].singularities, 0);
        assert!((rows[2].fp16_bound - 4.88e-4).abs() < 1e-5);
    }

    #[test]
    fn table2_matches_paper_n1024() {
        let (rows, improvement) = table2(1024);
        // LF cumulative: (1 + 163·4.88e-4)^10 − 1 ≈ 1.15.
        assert!((rows[0].cumulative - 1.15).abs() < 0.01, "{}", rows[0].cumulative);
        // Dual: 4.89e-3.
        assert!((rows[1].cumulative - 4.89e-3).abs() < 2e-5, "{}", rows[1].cumulative);
        // Improvement: 235×.
        assert!((improvement - 235.0).abs() < 2.0, "improvement {improvement}");
    }

    #[test]
    fn cumulative_linearizes_for_small_t() {
        // E ≈ m·t·eps when t·eps << 1.
        let e = cumulative_bound(1.0, 1e-8, 10);
        assert!((e - 1e-7).abs() / 1e-7 < 1e-5);
    }

    #[test]
    fn precision_sweep_shows_low_precision_specificity() {
        let sweep = precision_sweep(1024);
        // fp16: big improvement factor (≈235).
        assert!(sweep[0].3 > 100.0);
        // f64: bounds are both tiny and the *absolute* difference is
        // negligible (≈1e-16 vs 1e-13), even though the ratio persists.
        assert!(sweep[3].1 < 1e-12);
        assert!(sweep[3].2 < 1e-14);
    }

    #[test]
    fn serving_bound_dominates_paper_bound_and_separates_strategies() {
        use crate::fft::DType;
        let n = 1024;
        let m = 10;
        // The op-count form dominates the paper's normalized form at
        // every precision (it counts strictly more roundings).
        for dtype in DType::ALL {
            let eps = dtype.unit_roundoff();
            assert!(
                serving_bound_from_tmax(1.0, eps, m) > cumulative_bound(1.0, eps, m),
                "{dtype}"
            );
        }
        // Dual-select at fp16: a small, finite, usable bound.
        let dual = serving_bound(n, Strategy::DualSelect, DType::F16.unit_roundoff()).unwrap();
        assert!(dual > 0.0 && dual < 0.1, "dual fp16 serving bound {dual}");
        // Clamped LF at fp16: the stored 1e7 entry makes the a-priori
        // bound astronomically worse — the serving plane reports it
        // honestly instead of hiding the clamp.
        let lf = serving_bound(n, Strategy::LinzerFeig, DType::F16.unit_roundoff()).unwrap();
        assert!(lf > 1e6, "lf fp16 serving bound {lf}");
        assert!(lf / dual > 1e6);
        // No ratio table, no bound.
        assert_eq!(serving_bound(n, Strategy::Standard, DType::F16.unit_roundoff()), None);
        assert_eq!(serving_bound(100, Strategy::DualSelect, DType::F16.unit_roundoff()), None);
    }

    #[test]
    fn schedule_bound_degenerates_to_the_radix2_form() {
        // An all-radix-2 schedule must reproduce serving_bound_from_tmax
        // exactly — same ln1p terms, same expm1 fold.
        for (tmax, eps, m) in [(1.0, F16::EPSILON, 10u32), (163.0, 1e-3, 6), (0.5, 1e-7, 4)] {
            let radices = vec![2usize; m as usize];
            let sched = serving_bound_schedule(&radices, tmax, eps).unwrap();
            let classic = serving_bound_from_tmax(tmax, eps, m);
            assert_eq!(sched, classic, "tmax={tmax} eps={eps} m={m}");
        }
        // Unknown radix: no bound, not a wrong one.
        assert_eq!(radix_pass_ops(5), None);
        assert_eq!(serving_bound_schedule(&[2, 5], 1.0, 1e-3), None);
    }

    #[test]
    fn composite_sizes_get_finite_bounds() {
        use crate::fft::DType;
        let eps = DType::F16.unit_roundoff();
        for n in [12usize, 48, 96, 144, 1536] {
            let dual = serving_bound(n, Strategy::DualSelect, eps)
                .unwrap_or_else(|| panic!("no dual bound at n={n}"));
            assert!(dual > 0.0 && dual < 0.1, "n={n} dual bound {dual}");
            // Linzer–Feig tables at composite sizes hit the W^0
            // singularity clamp, and the bound says so.
            let lf = serving_bound(n, Strategy::LinzerFeig, eps).unwrap();
            assert!(lf / dual > 1e6, "n={n} lf={lf} dual={dual}");
            // Standard butterfly: still no ratio bound.
            assert_eq!(serving_bound(n, Strategy::Standard, eps), None);
        }
        // serving_bound_for_n mirrors the routing: pow2 → radix-2 form,
        // smooth composite → schedule form, other primes → None.
        assert_eq!(
            serving_bound_for_n(1024, 1.0, eps),
            Some(serving_bound_from_tmax(1.0, eps, 10))
        );
        let sched = serving_bound_schedule(&crate::kernel::plan_radices(96).unwrap(), 1.0, eps);
        assert_eq!(serving_bound_for_n(96, 1.0, eps), sched);
        assert_eq!(serving_bound_for_n(100, 1.0, eps), None);
        assert_eq!(serving_bound_for_n(1, 1.0, eps), None);
    }

    #[test]
    fn per_butterfly_bound_is_linear_in_t() {
        assert_eq!(per_butterfly_bound(2.0, 1e-3), 2e-3);
        assert_eq!(per_butterfly_bound(1.0, F16::EPSILON), F16::EPSILON);
    }
}
