//! Precomputed-ratio statistics — the evidence behind Table I.

use crate::fft::twiddle::CLAMP_EPS;
use crate::fft::{Direction, Strategy};

/// Statistics of a strategy's precomputed ratios over the flat twiddle
/// table `k ∈ [0, n/2)`.
#[derive(Clone, Debug, PartialEq)]
pub struct RatioStats {
    pub strategy: Strategy,
    pub n: usize,
    /// |t|max over entries whose denominator is not (near-)zero — the
    /// number the paper reports (163.0 for LF at N=1024).
    pub max_nonsingular: f64,
    /// Twiddle index attaining `max_nonsingular`.
    pub argmax_k: usize,
    /// Entries whose denominator is exactly ±0.0 (true singularities;
    /// 1 for LF at W^0).
    pub singular: usize,
    /// Entries with 0 < |denominator| < 1e-9 (the cosine path's k=N/4,
    /// cos(π/2) ≈ 6e-17 — the paper's "0*" footnote).
    pub near_singular: usize,
    /// |t|max including near-singular entries (>1e16 for cosine).
    pub max_with_near: f64,
    /// |t|max of the table as actually *stored* after epsilon clamping
    /// (1e7 for LF/cosine; equals max_nonsingular for dual-select).
    pub max_clamped: f64,
    /// Twiddles taking the cosine path (paper: 256 for N=1024 dual).
    pub cos_path: usize,
    /// Twiddles taking the sine path.
    pub sin_path: usize,
}

/// Compute [`RatioStats`] for `strategy` at size `n`.
pub fn ratio_stats(n: usize, strategy: Strategy) -> RatioStats {
    assert!(strategy != Strategy::Standard, "standard butterfly has no ratio");
    let half = n / 2;
    let mut st = RatioStats {
        strategy,
        n,
        max_nonsingular: 0.0,
        argmax_k: 0,
        singular: 0,
        near_singular: 0,
        max_with_near: 0.0,
        max_clamped: 0.0,
        cos_path: 0,
        sin_path: 0,
    };
    for k in 0..half {
        let theta = Direction::Forward.sign() * 2.0 * core::f64::consts::PI * k as f64 / n as f64;
        let (wr, wi) = (theta.cos(), theta.sin());
        let cosine = match strategy {
            Strategy::DualSelect => wr.abs() >= wi.abs(),
            Strategy::LinzerFeig => false,
            Strategy::Cosine => true,
            Strategy::Standard => unreachable!(),
        };
        if cosine {
            st.cos_path += 1;
        } else {
            st.sin_path += 1;
        }
        let denom = if cosine { wr } else { wi };
        let num = if cosine { wi } else { wr };

        if denom == 0.0 {
            st.singular += 1;
        } else {
            let t = (num / denom).abs();
            if denom.abs() < 1e-9 {
                st.near_singular += 1;
                st.max_with_near = st.max_with_near.max(t);
            } else {
                if t > st.max_nonsingular {
                    st.max_nonsingular = t;
                    st.argmax_k = k;
                }
                st.max_with_near = st.max_with_near.max(t);
            }
        }

        // The stored (clamped) value:
        let clamped_denom = if strategy != Strategy::DualSelect && denom.abs() < CLAMP_EPS {
            CLAMP_EPS
        } else {
            denom.abs()
        };
        if clamped_denom > 0.0 {
            st.max_clamped = st.max_clamped.max(num.abs() / clamped_denom);
        }
    }
    st
}

/// Sweep |t|max (non-singular) and path split across sizes — the data
/// series behind the generality bench.
pub fn sweep_sizes(strategy: Strategy, sizes: &[usize]) -> Vec<RatioStats> {
    sizes.iter().map(|&n| ratio_stats(n, strategy)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lf_row() {
        let st = ratio_stats(1024, Strategy::LinzerFeig);
        // |t|max = cot(π/512) = 163.0 at k=1
        assert!((st.max_nonsingular - 162.97).abs() < 0.05);
        assert_eq!(st.argmax_k, 1);
        assert_eq!(st.singular, 1); // W^0
        assert_eq!(st.near_singular, 0);
        assert_eq!(st.sin_path, 512);
        // Stored table after clamping holds 1e7.
        assert!((st.max_clamped - 1.0 / CLAMP_EPS).abs() / 1e7 < 1e-6);
    }

    #[test]
    fn table1_cosine_row() {
        let st = ratio_stats(1024, Strategy::Cosine);
        assert_eq!(st.singular, 0); // cos(π/2) != 0 exactly in f64
        assert_eq!(st.near_singular, 1); // the paper's 0* footnote
        assert!(st.max_with_near > 1e16); // paper: > 10^16
        assert_eq!(st.cos_path, 512);
    }

    #[test]
    fn table1_dual_row() {
        let st = ratio_stats(1024, Strategy::DualSelect);
        assert!((st.max_nonsingular - 1.0).abs() < 1e-12);
        assert_eq!(st.singular, 0);
        assert_eq!(st.near_singular, 0);
        assert_eq!(st.cos_path, 256); // paper §V: exact 50/50 split
        assert_eq!(st.sin_path, 256);
        assert_eq!(st.max_clamped, st.max_nonsingular);
    }

    #[test]
    fn dual_bound_holds_across_sweep() {
        for st in sweep_sizes(Strategy::DualSelect, &[4, 8, 16, 256, 4096, 65536]) {
            assert!(st.max_nonsingular <= 1.0 + 1e-15, "n={}", st.n);
            assert_eq!(st.singular, 0, "n={}", st.n);
            assert_eq!(st.near_singular, 0, "n={}", st.n);
        }
    }

    #[test]
    fn lf_max_grows_with_n() {
        // |t|max = cot(π/(N/2)) ≈ N/(2π): doubling N doubles the bound.
        let a = ratio_stats(512, Strategy::LinzerFeig).max_nonsingular;
        let b = ratio_stats(1024, Strategy::LinzerFeig).max_nonsingular;
        assert!((b / a - 2.0).abs() < 0.01);
    }

    #[test]
    fn split_is_even_for_multiples_of_8() {
        for n in [8usize, 64, 1024, 8192] {
            let st = ratio_stats(n, Strategy::DualSelect);
            assert_eq!(st.cos_path, n / 4, "n={n}");
            assert_eq!(st.sin_path, n / 4, "n={n}");
        }
    }
}
