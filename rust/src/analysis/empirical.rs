//! Measured (not bounded) transform error — the §V experimental
//! numbers: forward error vs the f64 DFT oracle and FFT→IFFT roundtrip
//! error, per strategy × precision × size.

use crate::dft;
use crate::fft::{PlanSpec, Strategy, Transform};
use crate::precision::{Real, SplitBuf};
use crate::util::metrics::rel_l2;
use crate::util::prng::Pcg32;

/// One measurement cell.
#[derive(Clone, Debug)]
pub struct ErrorMeasurement {
    pub strategy: Strategy,
    pub precision: &'static str,
    pub n: usize,
    /// Relative L2 error of the forward transform vs the f64 DFT.
    pub forward_rel_l2: f64,
    /// Relative L2 error of IFFT(FFT(x)) vs x.
    pub roundtrip_rel_l2: f64,
}

/// Generate a deterministic unit-scale test signal (uniform in [-1, 1];
/// keeps fp16 comfortably in range so overflow, when it happens, is the
/// *algorithm's* doing — i.e. the clamped ratio — not the input's).
pub fn test_signal(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg32::seed(seed);
    (
        (0..n).map(|_| rng.range(-1.0, 1.0)).collect(),
        (0..n).map(|_| rng.range(-1.0, 1.0)).collect(),
    )
}

/// Measure forward + roundtrip error for one (strategy, precision, n).
pub fn measure<T: Real>(n: usize, strategy: Strategy, seed: u64) -> ErrorMeasurement {
    let (re, im) = test_signal(n, seed);
    let (want_r, want_i) = dft::naive_dft(&re, &im, false);

    // Through the facade: powers of two keep the classic pinned plan,
    // {2,3}-smooth composites run the mixed-radix kernel, everything
    // else takes Bluestein — so the §V harness measures any size.
    let spec = PlanSpec::new(n).strategy(strategy);
    let fwd = spec.build::<T>().expect("plan");
    let inv = spec.inverse().build::<T>().expect("plan");

    let mut buf = SplitBuf::<T>::from_f64(&re, &im);
    let mut scratch = SplitBuf::zeroed(n);
    fwd.execute(&mut buf, &mut scratch);
    let (got_r, got_i) = buf.to_f64();
    let forward = rel_l2(&got_r, &got_i, &want_r, &want_i);

    inv.execute(&mut buf, &mut scratch);
    let (rt_r, rt_i) = buf.to_f64();
    // Compare against the precision-quantized input (what the transform
    // actually saw).
    let qbuf = SplitBuf::<T>::from_f64(&re, &im);
    let (qre, qim) = qbuf.to_f64();
    let roundtrip = rel_l2(&rt_r, &rt_i, &qre, &qim);

    ErrorMeasurement {
        strategy,
        precision: T::NAME,
        n,
        forward_rel_l2: forward,
        roundtrip_rel_l2: roundtrip,
    }
}

/// The full §V measurement grid for one size.
pub fn measure_grid(n: usize, seed: u64) -> Vec<ErrorMeasurement> {
    let mut out = Vec::new();
    for strategy in Strategy::ALL {
        out.push(measure::<f64>(n, strategy, seed));
        out.push(measure::<f32>(n, strategy, seed));
        out.push(measure::<crate::precision::F16>(n, strategy, seed));
        out.push(measure::<crate::precision::Bf16>(n, strategy, seed));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::F16;

    #[test]
    fn fp32_roundtrip_near_1e7_for_both_strategies() {
        // Paper §V "FP32 precision": ~1e-7 relative L2, equivalent.
        let lf = measure::<f32>(1024, Strategy::LinzerFeig, 1);
        let dual = measure::<f32>(1024, Strategy::DualSelect, 1);
        assert!(lf.roundtrip_rel_l2 < 1e-6, "{}", lf.roundtrip_rel_l2);
        assert!(dual.roundtrip_rel_l2 < 1e-6, "{}", dual.roundtrip_rel_l2);
        // "equivalent": within 4x of each other.
        let ratio = lf.roundtrip_rel_l2 / dual.roundtrip_rel_l2;
        assert!((0.25..4.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fp16_dual_select_within_cumulative_bound() {
        let m = measure::<F16>(1024, Strategy::DualSelect, 2);
        let bound = super::super::bounds::cumulative_bound(1.0, F16::EPSILON, 10);
        // Measured error is below the worst-case bound (and the bound is
        // not vacuous: within ~2 orders).
        assert!(m.forward_rel_l2 < bound * 10.0, "{} !< {}", m.forward_rel_l2, bound);
        assert!(m.forward_rel_l2 > bound / 100.0);
    }

    #[test]
    fn fp16_lf_is_meaningless() {
        // Paper: "rendering the FFT result meaningless".
        let m = measure::<F16>(1024, Strategy::LinzerFeig, 2);
        assert!(
            m.forward_rel_l2.is_nan() || m.forward_rel_l2 > 0.5,
            "LF fp16 err {}",
            m.forward_rel_l2
        );
    }

    #[test]
    fn grid_covers_all_cells() {
        let grid = measure_grid(64, 3);
        assert_eq!(grid.len(), 16); // 4 strategies × 4 precisions
        // f64 dual-select must be essentially exact.
        let d64 = grid
            .iter()
            .find(|m| m.strategy == Strategy::DualSelect && m.precision == "f64")
            .unwrap();
        assert!(d64.forward_rel_l2 < 1e-12);
    }
}
