//! Paper-style plain-text table rendering (no external crates).

/// A simple fixed-width table builder.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render with per-column width = max cell width.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Scientific notation like the paper ("4.88e-4"); special-cases inf.
pub fn sci(x: f64) -> String {
    if x.is_nan() {
        return "NaN".into();
    }
    if x.is_infinite() {
        return "divergent".into();
    }
    if x == 0.0 {
        return "0".into();
    }
    format!("{x:.2e}")
}

/// Fixed-point with sensible precision for ratio-style numbers.
pub fn fixed(x: f64) -> String {
    if x >= 1e6 {
        sci(x)
    } else if x >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("Demo", &["a", "long-header", "c"]);
        t.row(&["1".into(), "2".into(), "3".into()]);
        t.row(&["wide-cell".into(), "x".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("Demo"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, sep, 2 rows
        // All data lines equal width.
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(sci(4.88e-4), "4.88e-4");
        assert_eq!(sci(f64::INFINITY), "divergent");
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(f64::NAN), "NaN");
    }

    #[test]
    fn fixed_formatting() {
        assert_eq!(fixed(163.0123), "163.0");
        assert_eq!(fixed(1.0), "1.000");
        assert_eq!(fixed(2.5e16), "2.50e16");
    }
}
