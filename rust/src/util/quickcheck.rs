//! A miniature property-testing framework (proptest/quickcheck are not
//! available offline): seeded generators, a case runner that reports
//! the failing seed, and simple input shrinking for integer sizes.

use super::prng::Pcg32;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct QcConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for QcConfig {
    fn default() -> Self {
        QcConfig { cases: 64, seed: 0xF0F0_1234 }
    }
}

/// Run `prop` over `cases` seeded RNGs; panics with the failing case
/// seed so a failure is reproducible with `QcConfig { seed, cases: 1 }`.
pub fn check<F: FnMut(&mut Pcg32)>(name: &str, cfg: QcConfig, mut prop: F) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ super::prng::splitmix64(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg32::seed(case_seed);
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed on case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Draw a random power of two in `[2^lo_exp, 2^hi_exp]`.
pub fn pow2(rng: &mut Pcg32, lo_exp: u32, hi_exp: u32) -> usize {
    1usize << (lo_exp + (rng.below((hi_exp - lo_exp + 1) as usize) as u32))
}

/// Draw a random unit-scale split-complex signal.
pub fn signal(rng: &mut Pcg32, n: usize) -> (Vec<f64>, Vec<f64>) {
    (
        (0..n).map(|_| rng.range(-1.0, 1.0)).collect(),
        (0..n).map(|_| rng.range(-1.0, 1.0)).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("tautology", QcConfig { cases: 10, seed: 1 }, |_| {
            count += 1;
        });
        assert_eq!(count, 10);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always-false", QcConfig { cases: 3, seed: 2 }, |_| {
                panic!("boom");
            });
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn pow2_in_range() {
        let mut rng = Pcg32::seed(3);
        for _ in 0..100 {
            let n = pow2(&mut rng, 1, 10);
            assert!(n.is_power_of_two());
            assert!((2..=1024).contains(&n));
        }
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Pcg32::seed(9);
        let mut b = Pcg32::seed(9);
        assert_eq!(signal(&mut a, 8), signal(&mut b, 8));
    }
}
