//! Error metrics shared by the analysis module and tests.

/// Relative L2 error between complex signals given as split slices,
/// computed in f64: ||got - want|| / ||want||.
pub fn rel_l2(got_re: &[f64], got_im: &[f64], want_re: &[f64], want_im: &[f64]) -> f64 {
    assert_eq!(got_re.len(), want_re.len());
    assert_eq!(got_im.len(), want_im.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..got_re.len() {
        let dr = got_re[i] - want_re[i];
        let di = got_im[i] - want_im[i];
        num += dr * dr + di * di;
        den += want_re[i] * want_re[i] + want_im[i] * want_im[i];
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Max absolute componentwise error.
pub fn max_abs_err(got_re: &[f64], got_im: &[f64], want_re: &[f64], want_im: &[f64]) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..got_re.len() {
        worst = worst
            .max((got_re[i] - want_re[i]).abs())
            .max((got_im[i] - want_im[i]).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_l2_zero_on_equal() {
        let r = [1.0, 2.0];
        let i = [0.5, -1.0];
        assert_eq!(rel_l2(&r, &i, &r, &i), 0.0);
    }

    #[test]
    fn rel_l2_scales() {
        let want_r = [1.0, 0.0];
        let want_i = [0.0, 0.0];
        let got_r = [1.1, 0.0];
        let got_i = [0.0, 0.0];
        assert!((rel_l2(&got_r, &got_i, &want_r, &want_i) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn rel_l2_inf_when_reference_zero() {
        assert_eq!(rel_l2(&[1.0], &[0.0], &[0.0], &[0.0]), f64::INFINITY);
        assert_eq!(rel_l2(&[0.0], &[0.0], &[0.0], &[0.0]), 0.0);
    }

    #[test]
    fn max_abs_err_picks_worst() {
        let e = max_abs_err(&[1.0, 2.0], &[0.0, 0.0], &[1.0, 2.5], &[0.0, 0.1]);
        assert_eq!(e, 0.5);
    }
}
