//! Minimal JSON parser and writer — enough for
//! `artifacts/manifest.json` and the observability plane's snapshot
//! export.
//!
//! (No serde offline; this is a small recursive-descent parser with
//! precise error positions, supporting the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP, plus a [`fmt::Display`]
//! writer that round-trips what the parser accepts.)

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize to compact JSON text (the inverse of [`Json::parse`]
    /// up to number formatting; non-finite numbers render as `null`).
    pub fn render(&self) -> String {
        self.to_string()
    }
}

/// Compact JSON writer.  Strings are escaped per RFC 8259; non-finite
/// numbers (which JSON cannot represent) render as `null`.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    f.write_str("null")
                } else if x.fract() == 0.0 && x.abs() < 9.007_199_254_740_992e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_json_string(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{item}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_json_string(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_json_string(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

/// Parse error with byte offset.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {kw}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        0xf0..=0xf7 => 4,
                        _ => return Err(self.err("bad utf-8")),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("bad utf-8"))?;
                    }
                    let s = std::str::from_utf8(&self.src[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.bump();
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.bump();
        }
        if self.peek() == Some(b'.') {
            self.bump();
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.bump();
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.bump();
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn unicode_escape_and_utf8() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
    }

    #[test]
    fn writer_roundtrips_through_the_parser() {
        let src = r#"{"a":[1,2.5,{"b":"c\nd"}],"e":null,"f":true,"g":"é"}"#;
        let v = Json::parse(src).unwrap();
        let text = v.render();
        assert_eq!(Json::parse(&text).unwrap(), v);
        // Integral floats render without a decimal point; key order is
        // the BTreeMap's (sorted), so the output is deterministic.
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(0.5).render(), "0.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Str("x\"y\\z\u{1}".into()).render(), "\"x\\\"y\\\\z\\u0001\"");
    }

    #[test]
    fn parses_a_manifest_shape() {
        let src = r#"{"format":"hlo-text","version":1,
            "artifacts":[{"name":"fft_fwd_dual_n1024_b1_f32",
            "file":"fft_fwd_dual_n1024_b1_f32.hlo.txt","kind":"fft",
            "n":1024,"batch":1,"strategy":"dual","inverse":false,
            "dtype":"f32","inputs":[[1,1024],[1,1024]],
            "outputs":[[1,1024],[1,1024]],"sha256":"x"}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("n").unwrap().as_usize(), Some(1024));
        assert_eq!(arts[0].get("inverse").unwrap().as_bool(), Some(false));
    }
}
