//! Small shared utilities: deterministic PRNG, error metrics.

pub mod json;
pub mod metrics;
pub mod prng;
pub mod quickcheck;
