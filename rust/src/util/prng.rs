//! Deterministic pseudo-random generators for tests, workloads and
//! benches (no external crates available offline; PCG32 + SplitMix64
//! are small, fast and well-studied).

/// PCG32 (O'Neill 2014): 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6364136223846793005;

    /// Seed with a stream id derived from the seed (one generator per
    /// purpose — pass distinct seeds for independent streams).
    pub fn seed(seed: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (seed << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free enough for
    /// test workloads).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * core::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times for
    /// the serving workload traces).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        loop {
            let u = self.uniform();
            if u > 1e-300 {
                return -u.ln() / lambda;
            }
        }
    }
}

/// SplitMix64 — used for seeding.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seed(1);
        let mut b = Pcg32::seed(1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Pcg32::seed(1);
        let mut b = Pcg32::seed(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg32::seed(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seed(4);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::seed(5);
        let lambda = 4.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_stays_in_range() {
        let mut rng = Pcg32::seed(6);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
        assert_eq!(rng.below(1), 0);
    }
}
