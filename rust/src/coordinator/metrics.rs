//! Serving metrics — re-exported from the observability plane.
//!
//! The registry itself lives in [`crate::obs`] since the observability
//! plane landed (per-stage tracing, numerical-health telemetry and the
//! served stats surface grew around it); this module keeps the
//! historical `coordinator::Metrics` / `coordinator::MetricsSnapshot`
//! paths working.

pub use crate::obs::{DTypeCounts, Metrics, MetricsSnapshot};
