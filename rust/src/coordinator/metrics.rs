//! Serving metrics: lock-free counters + a log-bucketed latency
//! histogram (no external crates; buckets are powers of two in
//! microseconds, 1 µs .. ~17 s).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 25; // 2^0 .. 2^24 µs

/// Shared metrics sink (cheap to clone behind an Arc).
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Mean batch size so far.
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency quantile from the histogram (upper bucket
    /// edge, µs).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self
            .latency_buckets
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1); // upper edge of bucket 2^i..2^{i+1}
            }
        }
        1u64 << BUCKETS
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} failed={} batches={} mean_batch={:.2} p50={}us p99={}us",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_from_known_distribution() {
        let m = Metrics::new();
        // 90 requests at ~100µs (bucket 6: 64..128), 10 at ~10ms.
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(100));
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_millis(10));
        }
        let p50 = m.latency_quantile_us(0.5);
        let p99 = m.latency_quantile_us(0.99);
        assert!(p50 <= 256, "p50 {p50}");
        assert!(p99 >= 8192, "p99 {p99}");
        assert!(p99 > p50);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.99), 0);
        assert_eq!(m.mean_batch(), 0.0);
    }

    #[test]
    fn mean_batch_tracks() {
        let m = Metrics::new();
        m.record_batch(32);
        m.record_batch(16);
        assert_eq!(m.mean_batch(), 24.0);
    }

    #[test]
    fn summary_is_parseable() {
        let m = Metrics::new();
        m.submitted.fetch_add(5, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("submitted=5"));
    }
}
