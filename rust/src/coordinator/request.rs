//! Request/response types for the serving plane.
//!
//! Responses are zero-copy: a completed batch's [`FrameArena`] is
//! shared behind an `Arc` and every response holds (arena, frame
//! index) instead of per-request `Vec`s.  When all clients drop their
//! responses the arena's refcount falls to 1 and the server's
//! [`crate::fft::ArenaPool`] reclaims the allocation.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::fft::{FftError, FrameArena, Strategy};

/// What the request asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FftOp {
    Forward,
    Inverse,
    /// Radar pulse compression against the service's reference chirp.
    MatchedFilter,
}

/// Batching key: requests with the same key can share one executable
/// invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub n: usize,
    pub op: FftOp,
    pub strategy: Strategy,
}

/// A client request: one split-format frame.  The payload travels to
/// the intake thread, which deserializes it straight into the batch
/// arena (f64 → f32, one pass) and keeps only the [`RequestMeta`].
#[derive(Debug)]
pub struct FftRequest {
    pub id: u64,
    pub key: PlanKey,
    pub re: Vec<f64>,
    pub im: Vec<f64>,
    /// Where the response goes.
    pub reply: mpsc::Sender<FftResponse>,
    /// Set at submission (for queue-latency accounting).
    pub submitted: Instant,
    /// Backpressure permit — held until the response is sent, so the
    /// admission gate tracks true in-flight work.
    pub permit: Option<super::backpressure::Permit>,
}

/// What remains of a request once its payload has moved into the
/// batch arena: identity, reply channel, accounting.
#[derive(Debug)]
pub struct RequestMeta {
    pub id: u64,
    pub reply: mpsc::Sender<FftResponse>,
    pub submitted: Instant,
    pub permit: Option<super::backpressure::Permit>,
}

impl FftRequest {
    /// Split into (payload, meta) — the intake path.
    pub fn into_parts(self) -> (Vec<f64>, Vec<f64>, RequestMeta) {
        let FftRequest { id, re, im, reply, submitted, permit, .. } = self;
        (re, im, RequestMeta { id, reply, submitted, permit })
    }
}

/// The completed response: a zero-copy window into the batch's shared
/// result arena (empty on error).
#[derive(Clone, Debug)]
pub struct FftResponse {
    pub id: u64,
    /// The batch's result arena + this request's frame index.
    payload: Option<(Arc<FrameArena<f32>>, usize)>,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Queue + service time.
    pub latency: std::time::Duration,
    /// Typed error if the request failed.
    pub error: Option<FftError>,
}

impl FftResponse {
    /// A successful response viewing frame `frame` of `arena`.
    pub fn ok(
        id: u64,
        arena: Arc<FrameArena<f32>>,
        frame: usize,
        batch_size: usize,
        latency: std::time::Duration,
    ) -> Self {
        debug_assert!(frame < arena.frames());
        FftResponse { id, payload: Some((arena, frame)), batch_size, latency, error: None }
    }

    /// A failed response.
    pub fn err(
        id: u64,
        error: FftError,
        batch_size: usize,
        latency: std::time::Duration,
    ) -> Self {
        FftResponse { id, payload: None, batch_size, latency, error: Some(error) }
    }

    /// Real plane of the result frame (empty if the request failed).
    pub fn re(&self) -> &[f32] {
        match &self.payload {
            Some((arena, frame)) => arena.frame(*frame).0,
            None => &[],
        }
    }

    /// Imaginary plane of the result frame (empty if the request
    /// failed).
    pub fn im(&self) -> &[f32] {
        match &self.payload {
            Some((arena, frame)) => arena.frame(*frame).1,
            None => &[],
        }
    }

    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_key_equality_groups_requests() {
        let a = PlanKey { n: 1024, op: FftOp::Forward, strategy: Strategy::DualSelect };
        let b = PlanKey { n: 1024, op: FftOp::Forward, strategy: Strategy::DualSelect };
        let c = PlanKey { n: 1024, op: FftOp::Inverse, strategy: Strategy::DualSelect };
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut set = std::collections::HashSet::new();
        set.insert(a);
        set.insert(b);
        set.insert(c);
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn response_ok_flag_and_zero_copy_views() {
        let mut arena = FrameArena::<f32>::new(3);
        arena.push_frame_f64(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        arena.push_frame_f64(&[7.0, 8.0, 9.0], &[0.5, 1.5, 2.5]);
        let shared = Arc::new(arena);
        let ok = FftResponse::ok(1, shared.clone(), 1, 2, Default::default());
        assert!(ok.is_ok());
        assert_eq!(ok.re(), &[7.0, 8.0, 9.0]);
        assert_eq!(ok.im(), &[0.5, 1.5, 2.5]);
        // Two responses share one arena — no copies.
        let ok0 = FftResponse::ok(0, shared.clone(), 0, 2, Default::default());
        assert_eq!(ok0.re(), &[1.0, 2.0, 3.0]);
        assert_eq!(Arc::strong_count(&shared), 3);

        let bad = FftResponse::err(2, FftError::Unsupported("x"), 2, Default::default());
        assert!(!bad.is_ok());
        assert!(bad.re().is_empty());
        assert!(bad.im().is_empty());
    }

    #[test]
    fn request_into_parts_keeps_accounting() {
        let (tx, _rx) = mpsc::channel();
        let req = FftRequest {
            id: 42,
            key: PlanKey { n: 4, op: FftOp::Forward, strategy: Strategy::DualSelect },
            re: vec![1.0; 4],
            im: vec![2.0; 4],
            reply: tx,
            submitted: Instant::now(),
            permit: None,
        };
        let (re, im, meta) = req.into_parts();
        assert_eq!(re, vec![1.0; 4]);
        assert_eq!(im, vec![2.0; 4]);
        assert_eq!(meta.id, 42);
    }
}
