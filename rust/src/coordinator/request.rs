//! Request/response types for the serving plane.
//!
//! Since the dtype redesign the wire format is precision-polymorphic:
//! every request carries a [`DType`] in its [`PlanKey`] (so batches
//! only mix same-precision frames), payloads always travel as f64 and
//! are rounded **once** into the working precision at intake (the same
//! policy the twiddle tables use), and every response reports the
//! dtype it was computed in plus the a-priori error bound from
//! [`crate::analysis::bounds`] for its strategy × dtype.
//!
//! Responses are zero-copy: a completed batch's [`AnyArena`] is shared
//! behind an `Arc` and every response holds (arena, frame index)
//! instead of per-request `Vec`s.  When all clients drop their
//! responses the arena's refcount falls to 1 and the server's
//! [`crate::fft::AnyArenaPool`] reclaims the allocation.  f32
//! responses expose borrowed slices ([`FftResponse::re`]); other
//! dtypes read through the exact-widening [`FftResponse::re_f64`].

use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::fft::{AnyArena, DType, FftError, Strategy, StrategyChoice};
use crate::obs::{TraceHandle, TraceStamps};

/// What the request asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FftOp {
    Forward,
    Inverse,
    /// Radar pulse compression against the service's reference chirp.
    MatchedFilter,
}

/// Batching key: requests with the same key can share one executable
/// invocation.  The dtype is part of the key, so an f16 request never
/// lands in an f32 batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub n: usize,
    pub op: FftOp,
    pub strategy: Strategy,
    /// Working precision the batch computes (and stores results) in.
    pub dtype: DType,
}

/// Routing header of an externally-submitted request — the shape the
/// network plane's ingest hook
/// ([`crate::coordinator::Server::submit_routed`]) takes: a
/// caller-chosen response-correlation id plus the full per-request
/// plan selection.  The id is echoed on the [`FftResponse`] and only
/// needs to be unique per reply channel, not globally.
///
/// The strategy is a [`StrategyChoice`]: `Auto` resolves through the
/// server's loaded wisdom (else its default) *at admission*, so the
/// [`PlanKey`] a request batches under is always concrete — a tuned
/// request shares batches (and bits) with an explicit one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Route {
    pub id: u64,
    pub op: FftOp,
    pub dtype: DType,
    pub strategy: StrategyChoice,
}

/// A client request: one split-format frame.  The payload travels to
/// the intake thread, which deserializes it straight into the batch
/// arena (f64 → working dtype, one rounding pass) and keeps only the
/// [`RequestMeta`].
#[derive(Debug)]
pub struct FftRequest {
    pub id: u64,
    pub key: PlanKey,
    pub re: Vec<f64>,
    pub im: Vec<f64>,
    /// Where the response goes.
    pub reply: mpsc::Sender<FftResponse>,
    /// Set at submission (for queue-latency accounting).
    pub submitted: Instant,
    /// Backpressure permit — held until the response is sent, so the
    /// admission gate tracks true in-flight work.
    pub permit: Option<super::backpressure::Permit>,
}

/// What remains of a request once its payload has moved into the
/// batch arena: identity, reply channel, accounting.
#[derive(Debug)]
pub struct RequestMeta {
    pub id: u64,
    pub reply: mpsc::Sender<FftResponse>,
    pub submitted: Instant,
    pub permit: Option<super::backpressure::Permit>,
    /// Lifecycle stamps for the observability plane; initialized with
    /// every stage collapsed onto the admission instant and filled in
    /// as the request moves through the batcher and a worker.
    pub stamps: TraceStamps,
}

impl FftRequest {
    /// Split into (payload, meta) — the intake path.
    pub fn into_parts(self) -> (Vec<f64>, Vec<f64>, RequestMeta) {
        let FftRequest { id, re, im, reply, submitted, permit, .. } = self;
        (re, im, RequestMeta { id, reply, submitted, permit, stamps: TraceStamps::new(submitted) })
    }
}

/// The completed response: a zero-copy window into the batch's shared
/// result arena (empty on error), tagged with the working dtype.
#[derive(Clone, Debug)]
pub struct FftResponse {
    pub id: u64,
    /// The batch's result arena + this request's frame index.
    payload: Option<(Arc<AnyArena>, usize)>,
    /// Working precision the request was computed in (valid on both
    /// success and failure — it is the dtype that *would have* served).
    pub dtype: DType,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Queue + service time.
    pub latency: std::time::Duration,
    /// A-priori cumulative error bound for this request's
    /// strategy × dtype ([`crate::analysis::bounds::serving_bound`]);
    /// `None` when no ratio bound applies (standard butterfly,
    /// matched-filter composites, non-radix-2 sizes).
    pub bound: Option<f64>,
    /// Typed error if the request failed.
    pub error: Option<FftError>,
    /// Trace handle attached by the serving worker.  Shared by clones;
    /// the first [`FftResponse::finish_trace`] call (the TCP writer,
    /// right after the frame bytes flush) stamps "reply written" and
    /// records the trace; dropping the last clone is the fallback for
    /// in-process consumers and dead connections.
    trace: Option<Arc<TraceHandle>>,
}

impl FftResponse {
    /// A successful response viewing frame `frame` of `arena`.
    pub fn ok(
        id: u64,
        arena: Arc<AnyArena>,
        frame: usize,
        batch_size: usize,
        latency: std::time::Duration,
        bound: Option<f64>,
    ) -> Self {
        debug_assert!(frame < arena.frames());
        let dtype = arena.dtype();
        FftResponse {
            id,
            payload: Some((arena, frame)),
            dtype,
            batch_size,
            latency,
            bound,
            error: None,
            trace: None,
        }
    }

    /// A failed response.
    pub fn err(
        id: u64,
        error: FftError,
        dtype: DType,
        batch_size: usize,
        latency: std::time::Duration,
    ) -> Self {
        FftResponse {
            id,
            payload: None,
            dtype,
            batch_size,
            latency,
            bound: None,
            error: Some(error),
            trace: None,
        }
    }

    /// Attach a trace handle (serving worker, on the Ok path).
    pub fn with_trace(mut self, trace: Arc<TraceHandle>) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Finish the attached trace now (idempotent; no-op when the
    /// response carries none).  Called by the TCP writer immediately
    /// after the reply bytes flush, so the "write" stage measures real
    /// serialization + socket time.
    pub fn finish_trace(&self) {
        if let Some(t) = &self.trace {
            t.finish();
        }
    }

    /// Real plane of the result frame, borrowed zero-copy (empty if
    /// the request failed).
    ///
    /// Only f32 responses expose borrowed slices; for any other dtype
    /// this panics — read through [`FftResponse::re_f64`] instead.
    pub fn re(&self) -> &[f32] {
        match &self.payload {
            Some((arena, frame)) => {
                let a = arena.as_f32().unwrap_or_else(|| {
                    panic!("response dtype is {}; use re_f64()/im_f64()", self.dtype)
                });
                a.frame(*frame).0
            }
            None => &[],
        }
    }

    /// Imaginary plane of the result frame, borrowed zero-copy (empty
    /// if the request failed).  f32 only — see [`FftResponse::re`].
    pub fn im(&self) -> &[f32] {
        match &self.payload {
            Some((arena, frame)) => {
                let a = arena.as_f32().unwrap_or_else(|| {
                    panic!("response dtype is {}; use re_f64()/im_f64()", self.dtype)
                });
                a.frame(*frame).1
            }
            None => &[],
        }
    }

    /// Real plane widened exactly to f64 — works for every dtype
    /// (empty if the request failed).  The values are exactly what the
    /// working precision produced; widening loses nothing.
    pub fn re_f64(&self) -> Vec<f64> {
        match &self.payload {
            Some((arena, frame)) => arena.frame_f64(*frame).0,
            None => Vec::new(),
        }
    }

    /// Imaginary plane widened exactly to f64 — works for every dtype
    /// (empty if the request failed).
    pub fn im_f64(&self) -> Vec<f64> {
        match &self.payload {
            Some((arena, frame)) => arena.frame_f64(*frame).1,
            None => Vec::new(),
        }
    }

    /// The quantized result frame (codes + block exponent + per-frame
    /// bound), when the response was computed in a fixed-point dtype —
    /// the wire encoder's zero-copy read path.  `None` for float
    /// responses and failures.
    pub fn fixed_frame(&self) -> Option<crate::fixed::FixedFrameRef<'_>> {
        match &self.payload {
            Some((arena, frame)) => arena.fixed_frame(*frame),
            None => None,
        }
    }

    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::FrameArena;

    #[test]
    fn plan_key_equality_groups_requests() {
        let a = PlanKey {
            n: 1024,
            op: FftOp::Forward,
            strategy: Strategy::DualSelect,
            dtype: DType::F32,
        };
        let b = PlanKey {
            n: 1024,
            op: FftOp::Forward,
            strategy: Strategy::DualSelect,
            dtype: DType::F32,
        };
        let c = PlanKey {
            n: 1024,
            op: FftOp::Inverse,
            strategy: Strategy::DualSelect,
            dtype: DType::F32,
        };
        // Same shape, different working precision: distinct batch key.
        let d = PlanKey { dtype: DType::F16, ..a };
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
        let mut set = std::collections::HashSet::new();
        set.insert(a);
        set.insert(b);
        set.insert(c);
        set.insert(d);
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn response_ok_flag_and_zero_copy_views() {
        let mut arena = FrameArena::<f32>::new(3);
        arena.push_frame_f64(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        arena.push_frame_f64(&[7.0, 8.0, 9.0], &[0.5, 1.5, 2.5]);
        let shared = Arc::new(AnyArena::from(arena));
        let ok = FftResponse::ok(1, shared.clone(), 1, 2, Default::default(), Some(1e-6));
        assert!(ok.is_ok());
        assert_eq!(ok.dtype, DType::F32);
        assert_eq!(ok.bound, Some(1e-6));
        assert_eq!(ok.re(), &[7.0, 8.0, 9.0]);
        assert_eq!(ok.im(), &[0.5, 1.5, 2.5]);
        // Two responses share one arena — no copies.
        let ok0 = FftResponse::ok(0, shared.clone(), 0, 2, Default::default(), None);
        assert_eq!(ok0.re(), &[1.0, 2.0, 3.0]);
        assert_eq!(Arc::strong_count(&shared), 3);

        let bad = FftResponse::err(2, FftError::Unsupported("x"), DType::F32, 2, Default::default());
        assert!(!bad.is_ok());
        assert!(bad.re().is_empty());
        assert!(bad.im().is_empty());
        assert!(bad.re_f64().is_empty());
    }

    #[test]
    fn non_f32_responses_widen_exactly() {
        let mut arena = AnyArena::new(DType::F16, 3);
        // Exactly representable in binary16.
        arena.push_frame_f64(&[1.0, -0.5, 2.0], &[0.25, 4.0, -1.0]);
        let resp = FftResponse::ok(7, Arc::new(arena), 0, 1, Default::default(), Some(0.05));
        assert_eq!(resp.dtype, DType::F16);
        assert_eq!(resp.re_f64(), vec![1.0, -0.5, 2.0]);
        assert_eq!(resp.im_f64(), vec![0.25, 4.0, -1.0]);
    }

    #[test]
    #[should_panic(expected = "use re_f64()")]
    fn borrowed_f32_view_rejects_other_dtypes() {
        let mut arena = AnyArena::new(DType::F16, 2);
        arena.push_zeroed();
        let resp = FftResponse::ok(1, Arc::new(arena), 0, 1, Default::default(), None);
        let _ = resp.re();
    }

    #[test]
    fn request_into_parts_keeps_accounting() {
        let (tx, _rx) = mpsc::channel();
        let req = FftRequest {
            id: 42,
            key: PlanKey {
                n: 4,
                op: FftOp::Forward,
                strategy: Strategy::DualSelect,
                dtype: DType::F32,
            },
            re: vec![1.0; 4],
            im: vec![2.0; 4],
            reply: tx,
            submitted: Instant::now(),
            permit: None,
        };
        let (re, im, meta) = req.into_parts();
        assert_eq!(re, vec![1.0; 4]);
        assert_eq!(im, vec![2.0; 4]);
        assert_eq!(meta.id, 42);
    }
}
