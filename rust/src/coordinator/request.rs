//! Request/response types for the serving plane.

use std::sync::mpsc;
use std::time::Instant;

use crate::fft::{FftError, Strategy};

/// What the request asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FftOp {
    Forward,
    Inverse,
    /// Radar pulse compression against the service's reference chirp.
    MatchedFilter,
}

/// Batching key: requests with the same key can share one executable
/// invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub n: usize,
    pub op: FftOp,
    pub strategy: Strategy,
}

/// A client request: one split-format frame.
#[derive(Debug)]
pub struct FftRequest {
    pub id: u64,
    pub key: PlanKey,
    pub re: Vec<f64>,
    pub im: Vec<f64>,
    /// Where the response goes.
    pub reply: mpsc::Sender<FftResponse>,
    /// Set at submission (for queue-latency accounting).
    pub submitted: Instant,
    /// Backpressure permit — held until the response is sent, so the
    /// admission gate tracks true in-flight work.
    pub permit: Option<super::backpressure::Permit>,
}

/// The completed response.
#[derive(Clone, Debug)]
pub struct FftResponse {
    pub id: u64,
    pub re: Vec<f32>,
    pub im: Vec<f32>,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Queue + service time.
    pub latency: std::time::Duration,
    /// Typed error if the request failed.
    pub error: Option<FftError>,
}

impl FftResponse {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_key_equality_groups_requests() {
        let a = PlanKey { n: 1024, op: FftOp::Forward, strategy: Strategy::DualSelect };
        let b = PlanKey { n: 1024, op: FftOp::Forward, strategy: Strategy::DualSelect };
        let c = PlanKey { n: 1024, op: FftOp::Inverse, strategy: Strategy::DualSelect };
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut set = std::collections::HashSet::new();
        set.insert(a);
        set.insert(b);
        set.insert(c);
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn response_ok_flag() {
        let ok = FftResponse { id: 1, re: vec![], im: vec![], batch_size: 1, latency: Default::default(), error: None };
        assert!(ok.is_ok());
        let bad = FftResponse { error: Some(FftError::Unsupported("x")), ..ok.clone() };
        assert!(!bad.is_ok());
    }
}
