//! Bounded admission control: the service rejects (rather than
//! buffers without bound) when the in-flight request count hits the
//! configured limit — an explicit, testable backpressure policy.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared admission gate.
#[derive(Debug)]
pub struct Gate {
    limit: usize,
    in_flight: AtomicUsize,
}

impl Gate {
    pub fn new(limit: usize) -> Arc<Gate> {
        Arc::new(Gate { limit, in_flight: AtomicUsize::new(0) })
    }

    /// Try to admit one request; returns a guard on success.
    pub fn try_admit(self: &Arc<Gate>) -> Option<Permit> {
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.limit {
                return None;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(Permit { gate: self.clone() }),
                Err(now) => cur = now,
            }
        }
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn limit(&self) -> usize {
        self.limit
    }
}

/// RAII admission permit — releases the slot on drop (even on worker
/// panic paths, so the gate can never leak slots).
#[derive(Debug)]
pub struct Permit {
    gate: Arc<Gate>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.gate.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_limit() {
        let gate = Gate::new(2);
        let p1 = gate.try_admit().unwrap();
        let _p2 = gate.try_admit().unwrap();
        assert!(gate.try_admit().is_none());
        assert_eq!(gate.in_flight(), 2);
        drop(p1);
        assert_eq!(gate.in_flight(), 1);
        let _p3 = gate.try_admit().unwrap();
        assert!(gate.try_admit().is_none());
    }

    #[test]
    fn concurrent_admission_never_exceeds_limit() {
        let gate = Gate::new(8);
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let gate = gate.clone();
            let peak = peak.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    if let Some(_p) = gate.try_admit() {
                        let now = gate.in_flight();
                        peak.fetch_max(now, Ordering::Relaxed);
                        assert!(now <= 8);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::Relaxed) <= 8);
        assert_eq!(gate.in_flight(), 0);
    }
}
