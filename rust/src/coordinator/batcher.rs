//! The dynamic batching policy: group same-key requests, flush when a
//! batch fills (`max_batch`) or its oldest member has waited
//! `max_wait` — the size-or-deadline policy serving systems like vLLM
//! use.  Pure data structure (no threads) so the policy is unit
//! testable; the server drives it from its intake loop.
//!
//! Since the zero-copy redesign the batcher IS the intake
//! deserializer: `push` moves each request's f64 payload straight into
//! the batch's planar [`AnyArena`] (one rounding pass into the key's
//! working dtype) and keeps only the per-request [`RequestMeta`].
//! Batches group by the full [`PlanKey`] — `(n, op, strategy, dtype)`
//! — so mixed-precision traffic shares the coordinator but never a
//! batch.  Arenas come from a shared [`AnyArenaPool`], so a warm
//! serving plane opens batches without touching the allocator.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::fft::{AnyArena, AnyArenaPool};

use super::request::{FftRequest, PlanKey, RequestMeta};

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_micros(500) }
    }
}

/// A flushed batch ready for a worker: the frames, planar and
/// contiguous in `arena` (frame `i` belongs to `meta[i]`), stored in
/// the key's working dtype, plus the per-request reply/accounting
/// state.
#[derive(Debug)]
pub struct Batch {
    pub key: PlanKey,
    pub arena: AnyArena,
    pub meta: Vec<RequestMeta>,
    /// When the oldest request entered the batcher.
    pub opened: Instant,
    /// The policy's `max_batch` cap when this batch opened (reported in
    /// traces as the batch-occupancy denominator).
    pub capacity: usize,
}

impl Batch {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }
}

/// Accumulates requests per key and decides flushes.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    pool: Arc<AnyArenaPool>,
    pending: HashMap<PlanKey, Batch>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, pool: Arc<AnyArenaPool>) -> Self {
        Batcher { policy, pool, pending: HashMap::new() }
    }

    /// Add a request — its payload is deserialized into the batch
    /// arena here (rounding once into the key's dtype); returns a full
    /// batch if this push filled one.
    pub fn push(&mut self, req: FftRequest, now: Instant) -> Option<Batch> {
        let key = req.key;
        let max_batch = self.policy.max_batch;
        let pool = &self.pool;
        let batch = self.pending.entry(key).or_insert_with(|| {
            let mut arena = pool.take(key.dtype, key.n);
            arena.reserve_frames(max_batch);
            Batch {
                key,
                arena,
                meta: Vec::with_capacity(max_batch),
                opened: now,
                capacity: max_batch,
            }
        });
        let (re, im, mut meta) = req.into_parts();
        meta.stamps.batched = now;
        batch.arena.push_frame_f64(&re, &im);
        batch.meta.push(meta);
        if batch.meta.len() >= self.policy.max_batch {
            self.pending.remove(&key)
        } else {
            None
        }
    }

    /// Flush every batch whose oldest request has waited `max_wait`.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<PlanKey> = self
            .pending
            .iter()
            .filter(|(_, b)| now.duration_since(b.opened) >= self.policy.max_wait)
            .map(|(k, _)| *k)
            .collect();
        expired
            .into_iter()
            .filter_map(|k| self.pending.remove(&k))
            .collect()
    }

    /// Flush everything (drain / shutdown).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        self.pending.drain().map(|(_, b)| b).collect()
    }

    /// Time until the next deadline flush is due, if any batch is open.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending
            .values()
            .map(|b| {
                self.policy
                    .max_wait
                    .saturating_sub(now.duration_since(b.opened))
            })
            .min()
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.values().map(|b| b.meta.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FftOp;
    use crate::fft::{DType, Strategy};
    use std::sync::mpsc;

    fn batcher(policy: BatchPolicy) -> Batcher {
        Batcher::new(policy, Arc::new(AnyArenaPool::new()))
    }

    fn key(n: usize, op: FftOp) -> PlanKey {
        PlanKey { n, op, strategy: Strategy::DualSelect, dtype: DType::F32 }
    }

    fn req(id: u64, k: PlanKey) -> (FftRequest, mpsc::Receiver<super::super::request::FftResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            FftRequest {
                id,
                key: k,
                re: vec![id as f64; k.n],
                im: vec![0.0; k.n],
                reply: tx,
                submitted: Instant::now(),
                permit: None,
            },
            rx,
        )
    }

    #[test]
    fn fills_batch_at_max() {
        let mut b = batcher(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10) });
        let k = key(64, FftOp::Forward);
        let now = Instant::now();
        let mut keep = Vec::new();
        for id in 0..2 {
            let (r, rx) = req(id, k);
            keep.push(rx);
            assert!(b.push(r, now).is_none());
        }
        let (r, _rx) = req(2, k);
        let full = b.push(r, now).expect("third push fills");
        assert_eq!(full.len(), 3);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn push_deserializes_payload_into_arena() {
        let mut b = batcher(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        let k = key(8, FftOp::Forward);
        let now = Instant::now();
        let (r1, _x1) = req(7, k);
        assert!(b.push(r1, now).is_none());
        let (r2, _x2) = req(9, k);
        let full = b.push(r2, now).unwrap();
        assert_eq!(full.arena.frames(), 2);
        assert_eq!(full.arena.frame_len(), 8);
        assert_eq!(full.arena.dtype(), DType::F32);
        // Frame i belongs to meta[i]; payload rounded to f32.
        assert_eq!(full.meta[0].id, 7);
        assert_eq!(full.arena.as_f32().unwrap().frame(0).0, &[7.0f32; 8]);
        assert_eq!(full.arena.as_f32().unwrap().frame(1).0, &[9.0f32; 8]);
    }

    #[test]
    fn dtypes_do_not_mix_in_a_batch() {
        let mut b = batcher(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        let k32 = key(8, FftOp::Forward);
        let k16 = PlanKey { dtype: DType::F16, ..k32 };
        let (r1, _x1) = req(1, k32);
        let (r2, _x2) = req(2, k16);
        assert!(b.push(r1, now).is_none());
        // Same n/op/strategy, different dtype: opens a second batch.
        assert!(b.push(r2, now).is_none());
        assert_eq!(b.pending_requests(), 2);
        let (r3, _x3) = req(3, k16);
        let full = b.push(r3, now).expect("f16 batch fills");
        assert_eq!(full.key.dtype, DType::F16);
        assert_eq!(full.arena.dtype(), DType::F16);
        assert_eq!(full.len(), 2);
        // The f16 payload was rounded once into binary16 storage.
        assert_eq!(full.arena.frame_f64(0).0, vec![2.0; 8]);
        assert_eq!(b.pending_requests(), 1);
    }

    #[test]
    fn different_keys_do_not_mix() {
        let mut b = batcher(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        let (r1, _x1) = req(1, key(64, FftOp::Forward));
        let (r2, _x2) = req(2, key(64, FftOp::Inverse));
        assert!(b.push(r1, now).is_none());
        assert!(b.push(r2, now).is_none());
        assert_eq!(b.pending_requests(), 2);
        let (r3, _x3) = req(3, key(64, FftOp::Forward));
        let full = b.push(r3, now).unwrap();
        assert_eq!(full.key.op, FftOp::Forward);
        assert_eq!(full.len(), 2);
    }

    #[test]
    fn deadline_flush() {
        let mut b = batcher(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let (r, _x) = req(1, key(64, FftOp::Forward));
        b.push(r, t0);
        assert!(b.flush_expired(t0 + Duration::from_millis(1)).is_empty());
        let flushed = b.flush_expired(t0 + Duration::from_millis(6));
        assert_eq!(flushed.len(), 1);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = batcher(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(10) });
        let t0 = Instant::now();
        assert!(b.next_deadline(t0).is_none());
        let (r, _x) = req(1, key(64, FftOp::Forward));
        b.push(r, t0);
        let d = b.next_deadline(t0 + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn flush_all_drains() {
        let mut b = batcher(BatchPolicy::default());
        let now = Instant::now();
        let (r1, _x1) = req(1, key(64, FftOp::Forward));
        let (r2, _x2) = req(2, key(128, FftOp::Forward));
        b.push(r1, now);
        b.push(r2, now);
        let all = b.flush_all();
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn no_request_lost_under_mixed_flushes() {
        let mut b = batcher(BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        let t0 = Instant::now();
        let mut seen = 0usize;
        let mut keep = Vec::new();
        for id in 0..37u64 {
            let k = key(if id % 3 == 0 { 64 } else { 128 }, FftOp::Forward);
            let (r, rx) = req(id, k);
            keep.push(rx);
            if let Some(full) = b.push(r, t0) {
                assert_eq!(full.arena.frames(), full.len());
                seen += full.len();
            }
        }
        for batch in b.flush_expired(t0 + Duration::from_millis(2)) {
            seen += batch.len();
        }
        seen += b.flush_all().iter().map(|x| x.len()).sum::<usize>();
        assert_eq!(seen, 37);
    }
}
