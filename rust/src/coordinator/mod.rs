//! The Layer-3 serving coordinator: a dynamic-batching FFT service in
//! the style of vLLM's request router, on std threads + channels
//! (Python is never on this path; the compute backend is either the
//! native Rust FFT core or the AOT PJRT artifacts).
//!
//! Request flow:
//!
//! ```text
//! client → admit (backpressure) → batcher (group by plan key,
//!     flush on max_batch or max_wait) → worker pool (native plans or
//!     PJRT executables) → per-request response channel
//! ```
//!
//! * [`request`] — request/response types and plan keys
//! * [`metrics`] — latency histograms + throughput counters
//! * [`backpressure`] — bounded admission control
//! * [`batcher`] — the dynamic batching policy
//! * [`server`] — lifecycle: spawn, submit, drain, shutdown

pub mod backpressure;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{FftOp, FftRequest, FftResponse, PlanKey, RequestMeta};
pub use server::{Backend, Server, ServerConfig};
