//! The Layer-3 serving coordinator: a dynamic-batching FFT service in
//! the style of vLLM's request router, on std threads + channels
//! (Python is never on this path; the compute backend is either the
//! native Rust FFT core or the AOT PJRT artifacts).
//!
//! Request flow:
//!
//! ```text
//! client → admit (backpressure) → batcher (group by plan key —
//!     (n, op, strategy, dtype) — flush on max_batch or max_wait)
//!     → worker pool (native plans, any dtype; or PJRT executables,
//!       f32) → per-request response channel
//! ```
//!
//! The serving plane is precision-polymorphic: requests name a
//! [`crate::fft::DType`] (f64/f32/bf16/f16), intake rounds the f64
//! payload once into that working precision, workers execute through
//! the dtype-erased [`crate::fft::AnyTransform`], and responses report
//! the dtype plus the a-priori error bound for their strategy × dtype
//! — so an f16 dual-select request observably beats clamped
//! Linzer–Feig in the same serving path (the paper's headline claim,
//! served).
//!
//! * [`request`] — request/response types and plan keys
//! * [`metrics`] — latency histograms + per-dtype throughput counters
//! * [`backpressure`] — bounded admission control
//! * [`batcher`] — the dynamic batching policy
//! * [`server`] — lifecycle: spawn, submit, drain, shutdown

pub mod backpressure;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::{DTypeCounts, Metrics, MetricsSnapshot};
pub use request::{FftOp, FftRequest, FftResponse, PlanKey, RequestMeta, Route};
pub use server::{Backend, Server, ServerConfig};
