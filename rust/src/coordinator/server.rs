//! The serving loop: intake thread (batching) + worker pool (compute),
//! over either the native Rust FFT core or the PJRT artifact runtime.
//!
//! Precision-polymorphic, zero-copy data plane: intake deserializes
//! request payloads straight into a pooled dtype-tagged [`AnyArena`]
//! (one f64 → working-dtype rounding pass), workers resolve each
//! batch's [`PlanKey`] — `(n, op, strategy, dtype)` — to one
//! [`AnyTransform`] through a shared-nothing per-worker [`AnyPlanner`]
//! and run [`AnyTransform::execute_many_any`] over the arena with
//! per-dtype pooled scratch ([`AnyScratch`]) — after warmup the native
//! compute path does no heap allocation for any dtype it has seen
//! (the PJRT path, f32 only, still stages a `BatchF32` per chunk).
//! Responses share the result arena behind an `Arc` (no per-request
//! copies), report the working dtype plus the a-priori error bound
//! from [`crate::analysis::bounds`] for their strategy × dtype, and
//! the arena recycles through the [`AnyArenaPool`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::analysis::ratio::ratio_stats;
use crate::fft::{
    AnyArena, AnyArenaPool, AnyPlanner, AnyScratch, AnyTransform, DType, Direction, FftError,
    FftResult, Planner, Strategy, StrategyChoice,
};
use crate::runtime::literal::BatchF32;
use crate::runtime::{ArtifactKind, Engine};
use crate::signal::chirp::default_chirp;
use crate::signal::pulse::MatchedFilter;
use crate::tune::Wisdom;

use crate::obs::TraceHandle;

use super::backpressure::Gate;
use super::batcher::{Batch, BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{FftOp, FftRequest, FftResponse, PlanKey, Route};

/// Which compute plane serves the batches.
pub enum Backend {
    /// The native Rust FFT core (any working dtype).
    Native,
    /// The AOT JAX/Pallas artifacts via PJRT (f32 only).
    Pjrt { artifact_dir: std::path::PathBuf },
}

/// Server configuration.
pub struct ServerConfig {
    pub n: usize,
    pub strategy: Strategy,
    pub backend: Backend,
    pub policy: BatchPolicy,
    pub workers: usize,
    /// Max in-flight requests before admission rejects.
    pub queue_limit: usize,
    /// Reference pulse length for matched-filter requests.
    pub pulse_len: usize,
    /// Default working precision for [`Server::submit`] (requests can
    /// override per call with [`Server::submit_with`]).
    pub dtype: DType,
    /// Loaded tuning wisdom ([`crate::tune`]): `Auto`-strategy
    /// requests resolve through it at admission.  `None` (the
    /// default) means `Auto` always falls back to `strategy`.
    pub wisdom: Option<Arc<Wisdom>>,
}

impl ServerConfig {
    pub fn native(n: usize) -> Self {
        ServerConfig {
            n,
            strategy: Strategy::DualSelect,
            backend: Backend::Native,
            policy: BatchPolicy::default(),
            workers: 2,
            queue_limit: 4096,
            pulse_len: n / 4,
            dtype: DType::F32,
            wisdom: None,
        }
    }

    pub fn pjrt(n: usize, artifact_dir: impl Into<std::path::PathBuf>) -> Self {
        ServerConfig {
            backend: Backend::Pjrt { artifact_dir: artifact_dir.into() },
            ..ServerConfig::native(n)
        }
    }
}

enum IntakeMsg {
    Req(FftRequest),
    Drain(mpsc::Sender<()>),
    Shutdown,
}

enum WorkerMsg {
    Work(Batch),
    Sync(mpsc::Sender<()>),
    Stop,
}

/// Send-able recipe for building a worker's compute state (the PJRT
/// client is not `Send`, so each worker thread owns its own
/// [`Engine`], built from this recipe inside the thread).
#[derive(Clone)]
struct ComputeRecipe {
    n: usize,
    strategy: Strategy,
    pulse_len: usize,
    dtype: DType,
    artifact_dir: Option<std::path::PathBuf>,
}

/// Per-worker compute state.
struct ComputeCtx {
    n: usize,
    strategy: Strategy,
    planner: AnyPlanner,
    /// Matched filters built on demand per (strategy, dtype)
    /// (worker-local lock, uncontended; the server-default pair is
    /// built eagerly so a bad pulse config fails every batch
    /// immediately, as before).  Since the network plane landed,
    /// requests can override the strategy per call, so the key is the
    /// full pair.
    matched: Mutex<std::collections::HashMap<(Strategy, DType), AnyTransform>>,
    /// Zero-padded reference chirp for lazily-built matched filters.
    chirp: (Vec<f64>, Vec<f64>),
    /// |t|max of the *stored* (clamped) twiddle table per strategy,
    /// computed on first use — the dtype-independent part of the
    /// a-priori response bound (`None` when no ratio bound applies).
    tmax: Mutex<std::collections::HashMap<Strategy, Option<f64>>>,
    engine: Option<Engine>,
    /// Shared metrics sink: the worker reports its plan-cache hit/miss
    /// traffic here.
    metrics: Arc<Metrics>,
}

impl ComputeCtx {
    fn new(recipe: &ComputeRecipe, metrics: Arc<Metrics>) -> FftResult<Self> {
        let chirp = default_chirp(recipe.pulse_len);
        let engine = match &recipe.artifact_dir {
            None => None,
            Some(dir) => Some(Engine::new(dir)?),
        };
        let ctx = ComputeCtx {
            n: recipe.n,
            strategy: recipe.strategy,
            planner: AnyPlanner::new(),
            matched: Mutex::new(std::collections::HashMap::new()),
            chirp,
            tmax: Mutex::new(std::collections::HashMap::new()),
            engine,
            metrics,
        };
        // Warm the default strategy's ratio statistics and preflight
        // the default matched filter (validates the pulse/frame
        // configuration at worker start).  Fixed-point defaults skip
        // the filter preflight — the matched-filter composite is
        // float-only, and requesting it stays a per-request typed
        // error rather than poisoning the whole worker.
        let _ = ctx.tmax_for(recipe.strategy);
        if !recipe.dtype.is_fixed() {
            ctx.matched_for(recipe.strategy, recipe.dtype)?;
        }
        Ok(ctx)
    }

    /// |t|max of the stored table for `strategy` at this server's n,
    /// computed once per strategy the worker has seen — and reported
    /// into the numerical-health registry's per-strategy high-water on
    /// first computation.
    fn tmax_for(&self, strategy: Strategy) -> Option<f64> {
        let mut map = self.tmax.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(t) = map.get(&strategy) {
            return *t;
        }
        let t = if strategy == Strategy::Standard || self.n < 2 {
            None
        } else if self.n.is_power_of_two() {
            Some(ratio_stats(self.n, strategy).max_clamped)
        } else {
            // Composite 2^a·3^b sizes are served by the mixed-radix
            // kernel; its per-pass ratio tables carry the |t|max.
            crate::kernel::tables_tmax(self.n, strategy)
        };
        if let Some(tmax) = t {
            self.metrics.record_tmax(strategy, tmax);
        }
        map.insert(strategy, t);
        t
    }

    /// The matched filter computing in (`strategy`, `dtype`), built on
    /// first use.
    fn matched_for(&self, strategy: Strategy, dtype: DType) -> FftResult<AnyTransform> {
        let mut map = self.matched.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(t) = map.get(&(strategy, dtype)) {
            return Ok(t.clone());
        }
        let (cr, ci) = (&self.chirp.0, &self.chirp.1);
        let built = match dtype {
            DType::F64 => {
                let mf: MatchedFilter<f64> =
                    MatchedFilter::new(&Planner::new(), strategy, self.n, cr, ci)?;
                AnyTransform::F64(Arc::new(mf))
            }
            DType::F32 => {
                let mf: MatchedFilter<f32> =
                    MatchedFilter::new(&Planner::new(), strategy, self.n, cr, ci)?;
                AnyTransform::F32(Arc::new(mf))
            }
            DType::Bf16 => {
                let mf: MatchedFilter<crate::precision::Bf16> =
                    MatchedFilter::new(&Planner::new(), strategy, self.n, cr, ci)?;
                AnyTransform::Bf16(Arc::new(mf))
            }
            DType::F16 => {
                let mf: MatchedFilter<crate::precision::F16> =
                    MatchedFilter::new(&Planner::new(), strategy, self.n, cr, ci)?;
                AnyTransform::F16(Arc::new(mf))
            }
            DType::I16 | DType::I32 => {
                return Err(FftError::Unsupported(
                    "matched filtering is float-only (the composite's reference spectrum \
                     is not quantized); request dtype f64/f32/bf16/f16",
                ))
            }
        };
        map.insert((strategy, dtype), built.clone());
        Ok(built)
    }

    /// Resolve a batch key to the one transform that serves it,
    /// reporting the plan-cache outcome into the metrics.
    fn transform_for(&self, key: &PlanKey) -> FftResult<AnyTransform> {
        let direction = match key.op {
            FftOp::Forward => Direction::Forward,
            FftOp::Inverse => Direction::Inverse,
            FftOp::MatchedFilter => return self.matched_for(key.strategy, key.dtype),
        };
        let (t, hit) = self
            .planner
            .plan_tracked(key.n, key.strategy, direction, key.dtype)?;
        self.metrics.record_planner_lookup(hit);
        Ok(t)
    }

    /// The a-priori error bound attached to responses for `key` —
    /// [`crate::analysis::bounds::serving_bound`] evaluated with the
    /// `|t|max` cached per strategy.  None for the matched-filter
    /// composite (two transforms plus a pointwise product; no single
    /// eq.-(11) form applies) and for fixed-point dtypes, whose bound
    /// is signal-dependent: each executed frame carries its own from
    /// the quantization-noise model, read off the arena per response.
    fn bound_for(&self, key: &PlanKey) -> Option<f64> {
        if key.dtype.is_fixed() {
            return None;
        }
        match key.op {
            FftOp::MatchedFilter => None,
            FftOp::Forward | FftOp::Inverse => {
                self.tmax_for(key.strategy).and_then(|tmax| {
                    crate::analysis::bounds::serving_bound_for_n(
                        self.n,
                        tmax,
                        key.dtype.unit_roundoff(),
                    )
                })
            }
        }
    }

    /// Execute a batch in place: results overwrite the batch arena.
    fn run_batch(&self, batch: &mut Batch, scratch: &mut AnyScratch) -> FftResult<()> {
        match &self.engine {
            None => self.run_native(batch, scratch),
            Some(engine) => self.run_pjrt(engine, batch),
        }
    }

    fn run_native(&self, batch: &mut Batch, scratch: &mut AnyScratch) -> FftResult<()> {
        let transform = self.transform_for(&batch.key)?;
        transform.execute_many_any(&mut batch.arena, scratch)
    }

    fn run_pjrt(&self, engine: &Engine, batch: &mut Batch) -> FftResult<()> {
        // The AOT artifacts are compiled for f32 I/O; other dtypes are
        // a typed error (the native backend serves them).
        let arena = match &mut batch.arena {
            AnyArena::F32(a) => a,
            _ => {
                return Err(FftError::Unsupported(
                    "PJRT backend serves dtype f32 only (use the native backend)",
                ))
            }
        };
        let kind = match batch.key.op {
            FftOp::Forward | FftOp::Inverse => ArtifactKind::Fft,
            FftOp::MatchedFilter => ArtifactKind::MatchedFilter,
        };
        let inverse = batch.key.op == FftOp::Inverse;
        let count = batch.meta.len();

        // Pick the smallest artifact batch that fits, else the largest
        // (and chunk).
        let batches = engine
            .manifest
            .batches_for(kind, self.n, batch.key.strategy);
        // Inverse artifacts are registered separately; filter precisely.
        let available: Vec<usize> = engine
            .manifest
            .artifacts
            .iter()
            .filter(|a| {
                a.kind == kind && a.n == self.n && a.strategy == batch.key.strategy
                    && a.inverse == inverse
            })
            .map(|a| a.batch)
            .collect();
        let available = if available.is_empty() { batches } else { available };
        if available.is_empty() {
            return Err(FftError::Backend(format!(
                "no artifact for kind={kind:?} n={} strategy={} inverse={inverse}",
                self.n, batch.key.strategy
            )));
        }
        let fit = available.iter().copied().filter(|&b| b >= count).min();
        let chunk = fit.unwrap_or_else(|| available.iter().copied().max().unwrap());

        let mut start = 0usize;
        while start < count {
            let len = chunk.min(count - start);
            // Pad to the artifact's batch size, reading straight from
            // the arena (already f32).
            let mut input = BatchF32::zeroed(chunk, self.n);
            for row in 0..len {
                let (fre, fim) = arena.frame(start + row);
                input.re[row * self.n..(row + 1) * self.n].copy_from_slice(fre);
                input.im[row * self.n..(row + 1) * self.n].copy_from_slice(fim);
            }
            let name = crate::runtime::artifacts::artifact_name(
                kind,
                self.strategy,
                self.n,
                chunk,
                inverse,
            );
            let model = engine.load(&name)?;
            let result = &model.execute(&input)?[0];
            // Results land back in the arena — the response path is
            // identical for both backends.
            for row in 0..len {
                let (r, i) = result.row(row);
                let (fre, fim) = arena.frame_mut(start + row);
                fre.copy_from_slice(r);
                fim.copy_from_slice(i);
            }
            start += len;
        }
        Ok(())
    }
}

/// The coordinator server.
pub struct Server {
    intake_tx: mpsc::Sender<IntakeMsg>,
    metrics: Arc<Metrics>,
    gate: Arc<Gate>,
    n: usize,
    strategy: Strategy,
    dtype: DType,
    wisdom: Option<Arc<Wisdom>>,
    next_id: AtomicU64,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
    arena_pool: Arc<AnyArenaPool>,
    /// Set once by the first [`Server::shutdown`] (explicit or from
    /// [`Drop`]) so teardown never runs twice.
    stopped: std::sync::atomic::AtomicBool,
}

impl Server {
    /// Spawn intake + worker threads.
    pub fn start(cfg: ServerConfig) -> FftResult<Arc<Server>> {
        let metrics = Arc::new(Metrics::new());
        let gate = Gate::new(cfg.queue_limit);
        let arena_pool = Arc::new(AnyArenaPool::new());
        let recipe = ComputeRecipe {
            n: cfg.n,
            strategy: cfg.strategy,
            pulse_len: cfg.pulse_len,
            dtype: cfg.dtype,
            artifact_dir: match &cfg.backend {
                Backend::Native => None,
                Backend::Pjrt { artifact_dir } => {
                    // Preflight the whole backend up-front (manifest +
                    // engine construction) so an unusable PJRT runtime
                    // fails start() with a typed error the caller can
                    // fall back on — instead of accepting requests
                    // that would all come back FftError::Backend.
                    crate::runtime::Engine::new(artifact_dir)?;
                    Some(artifact_dir.clone())
                }
            },
        };

        let (intake_tx, intake_rx) = mpsc::channel::<IntakeMsg>();
        let (work_tx, work_rx) = mpsc::channel::<WorkerMsg>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let mut handles = Vec::new();

        // Worker pool: each worker builds its own ComputeCtx (the PJRT
        // client is not Send) and owns its own per-dtype Scratch pools.
        for w in 0..cfg.workers.max(1) {
            let work_rx = work_rx.clone();
            let recipe = recipe.clone();
            let metrics = metrics.clone();
            let pool = arena_pool.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fmafft-worker-{w}"))
                    .spawn(move || worker_loop(work_rx, recipe, metrics, pool))
                    .map_err(|e| FftError::Backend(format!("spawning worker: {e}")))?,
            );
        }

        // Intake / batching thread.
        let policy = cfg.policy;
        let metrics_in = metrics.clone();
        let workers = cfg.workers.max(1);
        let pool_in = arena_pool.clone();
        handles.push(
            std::thread::Builder::new()
                .name("fmafft-intake".into())
                .spawn(move || {
                    intake_loop(intake_rx, work_tx, policy, metrics_in, workers, pool_in)
                })
                .map_err(|e| FftError::Backend(format!("spawning intake: {e}")))?,
        );

        Ok(Arc::new(Server {
            intake_tx,
            metrics,
            gate,
            n: cfg.n,
            strategy: cfg.strategy,
            dtype: cfg.dtype,
            wisdom: cfg.wisdom,
            next_id: AtomicU64::new(1),
            handles: Mutex::new(handles),
            workers: cfg.workers.max(1),
            arena_pool,
            stopped: std::sync::atomic::AtomicBool::new(false),
        }))
    }

    /// Submit one frame in the server's default dtype; returns the
    /// response channel, or an error when backpressure rejects or the
    /// frame is malformed.
    pub fn submit(
        &self,
        op: FftOp,
        re: Vec<f64>,
        im: Vec<f64>,
    ) -> FftResult<mpsc::Receiver<FftResponse>> {
        self.submit_with(op, self.dtype, re, im)
    }

    /// Submit one frame with an explicit working precision — the
    /// precision-polymorphic entry point.  The payload is rounded once
    /// into `dtype` at intake; the response reports `dtype` back.
    pub fn submit_with(
        &self,
        op: FftOp,
        dtype: DType,
        re: Vec<f64>,
        im: Vec<f64>,
    ) -> FftResult<mpsc::Receiver<FftResponse>> {
        let (tx, rx) = mpsc::channel();
        let route = Route {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            op,
            dtype,
            strategy: self.strategy.into(),
        };
        self.submit_routed(route, re, im, tx)?;
        Ok(rx)
    }

    /// Submit a fully-specified request whose response is delivered to
    /// a caller-owned channel under a caller-chosen id — the ingest
    /// hook the network plane ([`crate::net`]) uses to fan many
    /// in-flight wire requests into one per-connection reply channel.
    ///
    /// The payload still deserializes straight into the coordinator's
    /// pooled batch arenas at intake; `route.strategy` overrides the
    /// server default per request (batches key on the full
    /// `(n, op, strategy, dtype)`, so mixed-strategy traffic shares
    /// the coordinator but never a batch).  Backpressure surfaces as
    /// [`FftError::Rejected`] without consuming the reply channel.
    pub fn submit_routed(
        &self,
        route: Route,
        re: Vec<f64>,
        im: Vec<f64>,
        reply: mpsc::Sender<FftResponse>,
    ) -> FftResult<()> {
        if re.len() != self.n || im.len() != self.n {
            let got = if re.len() != self.n { re.len() } else { im.len() };
            return Err(FftError::LengthMismatch { expected: self.n, got });
        }
        let Some(permit) = self.gate.try_admit() else {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(FftError::Rejected {
                in_flight: self.gate.in_flight(),
                limit: self.gate.limit(),
            });
        };
        // Resolve `Auto` to a concrete strategy *here*, before the
        // PlanKey forms: explicit choice > wisdom entry for
        // (n, dtype) > server default.  A tuned request therefore
        // batches with — and is bit-identical to — an explicit request
        // for the same resolved strategy; missing wisdom is counted
        // and served, never an error.
        let strategy = match route.strategy {
            StrategyChoice::Explicit(s) => s,
            StrategyChoice::Auto => {
                match self
                    .wisdom
                    .as_ref()
                    .and_then(|w| w.fft_strategy(self.n, route.dtype))
                {
                    Some(s) => {
                        self.metrics.record_tuned_selected(route.dtype);
                        s
                    }
                    None => {
                        self.metrics.record_auto_defaulted();
                        self.strategy
                    }
                }
            }
        };
        self.metrics.record_submitted(route.dtype);
        let req = FftRequest {
            id: route.id,
            key: PlanKey {
                n: self.n,
                op: route.op,
                strategy,
                dtype: route.dtype,
            },
            re,
            im,
            reply,
            submitted: Instant::now(),
            permit: Some(permit),
        };
        self.intake_tx
            .send(IntakeMsg::Req(req))
            .map_err(|_| FftError::ChannelClosed("server is shut down"))
    }

    /// Submit and block for the response (default dtype).
    pub fn submit_wait(&self, op: FftOp, re: Vec<f64>, im: Vec<f64>) -> FftResult<FftResponse> {
        self.submit_wait_with(op, self.dtype, re, im)
    }

    /// Submit with an explicit dtype and block for the response.
    pub fn submit_wait_with(
        &self,
        op: FftOp,
        dtype: DType,
        re: Vec<f64>,
        im: Vec<f64>,
    ) -> FftResult<FftResponse> {
        let rx = self.submit_with(op, dtype, re, im)?;
        rx.recv()
            .map_err(|_| FftError::ChannelClosed("response channel closed"))
    }

    /// Flush open batches and wait until every worker has drained.
    pub fn drain(&self) {
        let (tx, rx) = mpsc::channel();
        if self.intake_tx.send(IntakeMsg::Drain(tx)).is_ok() {
            for _ in 0..self.workers {
                let _ = rx.recv();
            }
        }
    }

    /// Drain and stop all threads.  Idempotent: the first call (from
    /// any thread, or from [`Drop`]) tears down; later calls return
    /// immediately, so explicit-shutdown-then-drop never double-joins.
    pub fn shutdown(&self) {
        if self.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        self.drain();
        let _ = self.intake_tx.send(IntakeMsg::Shutdown);
        let mut handles = self
            .handles
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// A shared handle to the metrics sink — what the network plane's
    /// stream [`crate::stream::SessionRegistry`] reports its gauges
    /// into.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// A shared handle to the loaded tuning wisdom (`None` when the
    /// server was booted without `--wisdom`) — the stream and graph
    /// registries consult it for overlap-save block lengths.
    pub fn wisdom_handle(&self) -> Option<Arc<Wisdom>> {
        self.wisdom.clone()
    }

    /// Point-in-time serving metrics (counters — aggregate and
    /// per-dtype — occupancy, queue depth, latency quantiles).
    pub fn snapshot(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn in_flight(&self) -> usize {
        self.gate.in_flight()
    }

    /// The server's default working precision.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// The server's default butterfly strategy.
    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    /// The frame length this server was planned for.
    pub fn frame_len(&self) -> usize {
        self.n
    }

    /// Arenas parked for recycling (observability for the zero-copy
    /// response path).
    pub fn arenas_parked(&self) -> usize {
        self.arena_pool.parked()
    }
}

/// Dropping the last handle tears the server down: drain, stop, join
/// — so `fftd` ctrl-c paths and tests that forget an explicit
/// [`Server::shutdown`] cannot leak worker threads.  The `stopped`
/// guard makes this a no-op after an explicit shutdown, and every
/// lock on the teardown path recovers from poisoning instead of
/// double-panicking.
impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn intake_loop(
    rx: mpsc::Receiver<IntakeMsg>,
    work_tx: mpsc::Sender<WorkerMsg>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    workers: usize,
    pool: Arc<AnyArenaPool>,
) {
    let mut batcher = Batcher::new(policy, pool);
    loop {
        let wait = batcher
            .next_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(IntakeMsg::Req(req)) => {
                let now = Instant::now();
                if let Some(batch) = batcher.push(req, now) {
                    metrics.record_batch(batch.len(), policy.max_batch);
                    let _ = work_tx.send(WorkerMsg::Work(batch));
                }
                metrics.set_queue_depth(batcher.pending_requests());
            }
            Ok(IntakeMsg::Drain(ack)) => {
                for batch in batcher.flush_all() {
                    metrics.record_batch(batch.len(), policy.max_batch);
                    let _ = work_tx.send(WorkerMsg::Work(batch));
                }
                metrics.set_queue_depth(0);
                // One sync per worker: each worker answers once it has
                // finished everything queued before the sync.
                for _ in 0..workers {
                    let _ = work_tx.send(WorkerMsg::Sync(ack.clone()));
                }
            }
            Ok(IntakeMsg::Shutdown) => {
                for batch in batcher.flush_all() {
                    metrics.record_batch(batch.len(), policy.max_batch);
                    let _ = work_tx.send(WorkerMsg::Work(batch));
                }
                metrics.set_queue_depth(0);
                for _ in 0..workers {
                    let _ = work_tx.send(WorkerMsg::Stop);
                }
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                for batch in batcher.flush_expired(Instant::now()) {
                    metrics.record_batch(batch.len(), policy.max_batch);
                    let _ = work_tx.send(WorkerMsg::Work(batch));
                }
                metrics.set_queue_depth(batcher.pending_requests());
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for _ in 0..workers {
                    let _ = work_tx.send(WorkerMsg::Stop);
                }
                return;
            }
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<WorkerMsg>>>,
    recipe: ComputeRecipe,
    metrics: Arc<Metrics>,
    pool: Arc<AnyArenaPool>,
) {
    // Build the per-thread compute state; if that fails every batch is
    // answered with the error.  The per-dtype Scratch pools live as
    // long as the worker — after the first batch of each dtype the
    // compute path stops allocating.
    let ctx = ComputeCtx::new(&recipe, metrics.clone());
    let mut scratch = AnyScratch::new();
    let mut batches_seen = 0u64;
    loop {
        let msg = {
            // Poison recovery: a sibling worker that panicked while
            // receiving must not take the whole pool down with it.
            let guard = rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            guard.recv()
        };
        match msg {
            Ok(WorkerMsg::Work(mut batch)) => {
                let dequeued = Instant::now();
                let size = batch.len();
                let capacity = batch.capacity;
                let key = batch.key;
                batches_seen += 1;
                // Sampled self-check (first batch, then every 64th):
                // keep frame 0's input before the in-place execute so
                // it can be re-run in f64 afterwards.
                let sample = match &ctx {
                    Ok(_) if batches_seen % 64 == 1 && key.op != FftOp::MatchedFilter => {
                        Some(batch.arena.frame_f64(0))
                    }
                    _ => None,
                };
                let result = match &ctx {
                    Ok(ctx) => ctx.run_batch(&mut batch, &mut scratch),
                    Err(e) => Err(e.clone()),
                };
                let executed = Instant::now();
                let bound = match &ctx {
                    Ok(ctx) => ctx.bound_for(&key),
                    Err(_) => None,
                };
                // Quantizer clamps counted while this batch's frames
                // were ingested (fixed-point arenas only).
                metrics.record_fixed_saturations(batch.arena.saturations());
                let Batch { arena, meta, .. } = batch;
                match result {
                    Ok(()) => {
                        // Share the result arena across all responses
                        // (zero copies), then park it for recycling.
                        let shared = Arc::new(arena);
                        if let (Some(input), Ok(ctx)) = (sample, &ctx) {
                            sampled_self_check(
                                ctx, &key, input, &shared, bound, &mut scratch, &metrics,
                            );
                        }
                        for (frame, m) in meta.into_iter().enumerate() {
                            metrics.record_completed(key.dtype);
                            let latency = m.submitted.elapsed();
                            metrics.record_latency(latency);
                            // Fixed-point frames carry their own
                            // signal-dependent bound; floats use the
                            // batch-wide eq. (11) one.
                            let frame_bound = shared.frame_bound(frame).or(bound);
                            let mut stamps = m.stamps;
                            stamps.dequeued = dequeued;
                            stamps.executed = executed;
                            let trace = Arc::new(TraceHandle::new(
                                stamps,
                                key.n as u32,
                                key.op,
                                key.strategy,
                                key.dtype,
                                size as u32,
                                capacity as u32,
                                metrics.clone(),
                            ));
                            let _ = m.reply.send(
                                FftResponse::ok(
                                    m.id,
                                    shared.clone(),
                                    frame,
                                    size,
                                    latency,
                                    frame_bound,
                                )
                                .with_trace(trace),
                            );
                            drop(m.permit);
                        }
                        pool.recycle(shared);
                    }
                    Err(e) => {
                        for m in meta {
                            metrics.record_failed(key.dtype);
                            let _ = m.reply.send(FftResponse::err(
                                m.id,
                                e.clone(),
                                key.dtype,
                                size,
                                m.submitted.elapsed(),
                            ));
                            drop(m.permit);
                        }
                        pool.recycle(Arc::new(arena));
                    }
                }
            }
            Ok(WorkerMsg::Sync(ack)) => {
                let _ = ack.send(());
            }
            Ok(WorkerMsg::Stop) | Err(_) => return,
        }
    }
}

/// Server-side sampled self-check: re-run one frame of a completed
/// batch through the f64 reference plan and record the observed
/// relative error against the a-priori bound the responses carry —
/// the same [`Metrics::record_tightness`] path `client --verify`
/// feeds.  Runs on ~1/64 batches, so allocation here is off the
/// per-request hot path.
fn sampled_self_check(
    ctx: &ComputeCtx,
    key: &PlanKey,
    input: (Vec<f64>, Vec<f64>),
    result: &AnyArena,
    batch_bound: Option<f64>,
    scratch: &mut AnyScratch,
    metrics: &Metrics,
) {
    let Some(bound) = result.frame_bound(0).or(batch_bound) else {
        return; // no a-priori bound applies (standard butterfly, …)
    };
    if !bound.is_finite() || bound <= 0.0 {
        return;
    }
    let ref_key = PlanKey { dtype: DType::F64, ..*key };
    let Ok(reference) = ctx.transform_for(&ref_key) else {
        return;
    };
    let mut ref_arena = AnyArena::new(DType::F64, key.n);
    ref_arena.push_frame_f64(&input.0, &input.1);
    if reference.execute_many_any(&mut ref_arena, scratch).is_err() {
        return;
    }
    let (rr, ri) = ref_arena.frame_f64(0);
    let (or, oi) = result.frame_f64(0);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for k in 0..key.n {
        let dr = or[k] - rr[k];
        let di = oi[k] - ri[k];
        num += dr * dr + di * di;
        den += rr[k] * rr[k] + ri[k] * ri[k];
    }
    if den <= 0.0 {
        return; // zero reference spectrum: relative error is undefined
    }
    metrics.record_tightness(key.dtype, key.strategy, (num / den).sqrt(), bound);
}
