//! The serving loop: intake thread (batching) + worker pool (compute),
//! over either the native Rust FFT core or the PJRT artifact runtime.
//!
//! Zero-copy data plane: intake deserializes request payloads straight
//! into a pooled planar [`FrameArena`] (one f64→f32 pass), workers
//! resolve each batch's [`PlanKey`] to one `Arc<dyn Transform<f32>>`
//! and run [`Transform::execute_many`] over the arena view with a
//! per-worker pooled [`Scratch`] — after warmup the native compute
//! path does no heap allocation (the PJRT path still stages a
//! `BatchF32` per chunk).  Responses share the result arena behind an
//! `Arc` (no per-request copies); once every client drops its
//! response the arena recycles through the [`ArenaPool`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::fft::{
    ArenaPool, Direction, FftError, FftResult, Planner, Scratch, Strategy, Transform,
};
use crate::runtime::literal::BatchF32;
use crate::runtime::{ArtifactKind, Engine};
use crate::signal::chirp::default_chirp;
use crate::signal::pulse::MatchedFilter;

use super::backpressure::Gate;
use super::batcher::{Batch, BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{FftOp, FftRequest, FftResponse, PlanKey};

/// Which compute plane serves the batches.
pub enum Backend {
    /// The native Rust FFT core (f32 working precision).
    Native,
    /// The AOT JAX/Pallas artifacts via PJRT.
    Pjrt { artifact_dir: std::path::PathBuf },
}

/// Server configuration.
pub struct ServerConfig {
    pub n: usize,
    pub strategy: Strategy,
    pub backend: Backend,
    pub policy: BatchPolicy,
    pub workers: usize,
    /// Max in-flight requests before admission rejects.
    pub queue_limit: usize,
    /// Reference pulse length for matched-filter requests.
    pub pulse_len: usize,
}

impl ServerConfig {
    pub fn native(n: usize) -> Self {
        ServerConfig {
            n,
            strategy: Strategy::DualSelect,
            backend: Backend::Native,
            policy: BatchPolicy::default(),
            workers: 2,
            queue_limit: 4096,
            pulse_len: n / 4,
        }
    }

    pub fn pjrt(n: usize, artifact_dir: impl Into<std::path::PathBuf>) -> Self {
        ServerConfig {
            backend: Backend::Pjrt { artifact_dir: artifact_dir.into() },
            ..ServerConfig::native(n)
        }
    }
}

enum IntakeMsg {
    Req(FftRequest),
    Drain(mpsc::Sender<()>),
    Shutdown,
}

enum WorkerMsg {
    Work(Batch),
    Sync(mpsc::Sender<()>),
    Stop,
}

/// Send-able recipe for building a worker's compute state (the PJRT
/// client is not `Send`, so each worker thread owns its own
/// [`Engine`], built from this recipe inside the thread).
#[derive(Clone)]
struct ComputeRecipe {
    n: usize,
    strategy: Strategy,
    pulse_len: usize,
    artifact_dir: Option<std::path::PathBuf>,
}

/// Per-worker compute state.
struct ComputeCtx {
    n: usize,
    strategy: Strategy,
    planner: Planner<f32>,
    matched: Arc<MatchedFilter<f32>>,
    engine: Option<Engine>,
}

impl ComputeCtx {
    fn new(recipe: &ComputeRecipe) -> FftResult<Self> {
        let planner = Planner::<f32>::new();
        let (cr, ci) = default_chirp(recipe.pulse_len);
        let matched =
            Arc::new(MatchedFilter::new(&planner, recipe.strategy, recipe.n, &cr, &ci)?);
        let engine = match &recipe.artifact_dir {
            None => None,
            Some(dir) => Some(Engine::new(dir)?),
        };
        Ok(ComputeCtx {
            n: recipe.n,
            strategy: recipe.strategy,
            planner,
            matched,
            engine,
        })
    }

    /// Resolve a batch key to the one transform that serves it.
    fn transform_for(&self, key: &PlanKey) -> FftResult<Arc<dyn Transform<f32>>> {
        match key.op {
            FftOp::Forward => self.planner.plan(key.n, key.strategy, Direction::Forward),
            FftOp::Inverse => self.planner.plan(key.n, key.strategy, Direction::Inverse),
            FftOp::MatchedFilter => Ok(self.matched.clone() as Arc<dyn Transform<f32>>),
        }
    }

    /// Execute a batch in place: results overwrite the batch arena.
    fn run_batch(&self, batch: &mut Batch, scratch: &mut Scratch<f32>) -> FftResult<()> {
        match &self.engine {
            None => self.run_native(batch, scratch),
            Some(engine) => self.run_pjrt(engine, batch),
        }
    }

    fn run_native(&self, batch: &mut Batch, scratch: &mut Scratch<f32>) -> FftResult<()> {
        let transform = self.transform_for(&batch.key)?;
        transform.execute_many(batch.arena.view_mut(), scratch);
        Ok(())
    }

    fn run_pjrt(&self, engine: &Engine, batch: &mut Batch) -> FftResult<()> {
        let kind = match batch.key.op {
            FftOp::Forward | FftOp::Inverse => ArtifactKind::Fft,
            FftOp::MatchedFilter => ArtifactKind::MatchedFilter,
        };
        let inverse = batch.key.op == FftOp::Inverse;
        let count = batch.len();

        // Pick the smallest artifact batch that fits, else the largest
        // (and chunk).
        let batches = engine
            .manifest
            .batches_for(kind, self.n, batch.key.strategy);
        // Inverse artifacts are registered separately; filter precisely.
        let available: Vec<usize> = engine
            .manifest
            .artifacts
            .iter()
            .filter(|a| {
                a.kind == kind && a.n == self.n && a.strategy == batch.key.strategy
                    && a.inverse == inverse
            })
            .map(|a| a.batch)
            .collect();
        let available = if available.is_empty() { batches } else { available };
        if available.is_empty() {
            return Err(FftError::Backend(format!(
                "no artifact for kind={kind:?} n={} strategy={} inverse={inverse}",
                self.n, batch.key.strategy
            )));
        }
        let fit = available.iter().copied().filter(|&b| b >= count).min();
        let chunk = fit.unwrap_or_else(|| available.iter().copied().max().unwrap());

        let mut start = 0usize;
        while start < count {
            let len = chunk.min(count - start);
            // Pad to the artifact's batch size, reading straight from
            // the arena (already f32).
            let mut input = BatchF32::zeroed(chunk, self.n);
            for row in 0..len {
                let (fre, fim) = batch.arena.frame(start + row);
                input.re[row * self.n..(row + 1) * self.n].copy_from_slice(fre);
                input.im[row * self.n..(row + 1) * self.n].copy_from_slice(fim);
            }
            let name = crate::runtime::artifacts::artifact_name(
                kind,
                self.strategy,
                self.n,
                chunk,
                inverse,
            );
            let model = engine.load(&name)?;
            let result = &model.execute(&input)?[0];
            // Results land back in the arena — the response path is
            // identical for both backends.
            for row in 0..len {
                let (r, i) = result.row(row);
                let (fre, fim) = batch.arena.frame_mut(start + row);
                fre.copy_from_slice(r);
                fim.copy_from_slice(i);
            }
            start += len;
        }
        Ok(())
    }
}

/// The coordinator server.
pub struct Server {
    intake_tx: mpsc::Sender<IntakeMsg>,
    metrics: Arc<Metrics>,
    gate: Arc<Gate>,
    n: usize,
    strategy: Strategy,
    next_id: AtomicU64,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
    arena_pool: Arc<ArenaPool<f32>>,
}

impl Server {
    /// Spawn intake + worker threads.
    pub fn start(cfg: ServerConfig) -> FftResult<Arc<Server>> {
        let metrics = Arc::new(Metrics::new());
        let gate = Gate::new(cfg.queue_limit);
        let arena_pool = Arc::new(ArenaPool::<f32>::new());
        let recipe = ComputeRecipe {
            n: cfg.n,
            strategy: cfg.strategy,
            pulse_len: cfg.pulse_len,
            artifact_dir: match &cfg.backend {
                Backend::Native => None,
                Backend::Pjrt { artifact_dir } => {
                    // Preflight the whole backend up-front (manifest +
                    // engine construction) so an unusable PJRT runtime
                    // fails start() with a typed error the caller can
                    // fall back on — instead of accepting requests
                    // that would all come back FftError::Backend.
                    crate::runtime::Engine::new(artifact_dir)?;
                    Some(artifact_dir.clone())
                }
            },
        };

        let (intake_tx, intake_rx) = mpsc::channel::<IntakeMsg>();
        let (work_tx, work_rx) = mpsc::channel::<WorkerMsg>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let mut handles = Vec::new();

        // Worker pool: each worker builds its own ComputeCtx (the PJRT
        // client is not Send) and owns its own Scratch pool.
        for w in 0..cfg.workers.max(1) {
            let work_rx = work_rx.clone();
            let recipe = recipe.clone();
            let metrics = metrics.clone();
            let pool = arena_pool.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fmafft-worker-{w}"))
                    .spawn(move || worker_loop(work_rx, recipe, metrics, pool))
                    .map_err(|e| FftError::Backend(format!("spawning worker: {e}")))?,
            );
        }

        // Intake / batching thread.
        let policy = cfg.policy;
        let metrics_in = metrics.clone();
        let workers = cfg.workers.max(1);
        let pool_in = arena_pool.clone();
        handles.push(
            std::thread::Builder::new()
                .name("fmafft-intake".into())
                .spawn(move || {
                    intake_loop(intake_rx, work_tx, policy, metrics_in, workers, pool_in)
                })
                .map_err(|e| FftError::Backend(format!("spawning intake: {e}")))?,
        );

        Ok(Arc::new(Server {
            intake_tx,
            metrics,
            gate,
            n: cfg.n,
            strategy: cfg.strategy,
            next_id: AtomicU64::new(1),
            handles: Mutex::new(handles),
            workers: cfg.workers.max(1),
            arena_pool,
        }))
    }

    /// Submit one frame; returns the response channel, or an error when
    /// backpressure rejects or the frame is malformed.
    pub fn submit(
        &self,
        op: FftOp,
        re: Vec<f64>,
        im: Vec<f64>,
    ) -> FftResult<mpsc::Receiver<FftResponse>> {
        if re.len() != self.n || im.len() != self.n {
            return Err(FftError::LengthMismatch { expected: self.n, got: re.len() });
        }
        let Some(permit) = self.gate.try_admit() else {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(FftError::Rejected {
                in_flight: self.gate.in_flight(),
                limit: self.gate.limit(),
            });
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let req = FftRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            key: PlanKey { n: self.n, op, strategy: self.strategy },
            re,
            im,
            reply: tx,
            submitted: Instant::now(),
            permit: Some(permit),
        };
        self.intake_tx
            .send(IntakeMsg::Req(req))
            .map_err(|_| FftError::ChannelClosed("server is shut down"))?;
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, op: FftOp, re: Vec<f64>, im: Vec<f64>) -> FftResult<FftResponse> {
        let rx = self.submit(op, re, im)?;
        rx.recv()
            .map_err(|_| FftError::ChannelClosed("response channel closed"))
    }

    /// Flush open batches and wait until every worker has drained.
    pub fn drain(&self) {
        let (tx, rx) = mpsc::channel();
        if self.intake_tx.send(IntakeMsg::Drain(tx)).is_ok() {
            for _ in 0..self.workers {
                let _ = rx.recv();
            }
        }
    }

    /// Drain and stop all threads.
    pub fn shutdown(&self) {
        self.drain();
        let _ = self.intake_tx.send(IntakeMsg::Shutdown);
        let mut handles = self
            .handles
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Point-in-time serving metrics (counters, occupancy, queue
    /// depth, latency quantiles).
    pub fn snapshot(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn in_flight(&self) -> usize {
        self.gate.in_flight()
    }

    /// Arenas parked for recycling (observability for the zero-copy
    /// response path).
    pub fn arenas_parked(&self) -> usize {
        self.arena_pool.parked()
    }
}

fn intake_loop(
    rx: mpsc::Receiver<IntakeMsg>,
    work_tx: mpsc::Sender<WorkerMsg>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    workers: usize,
    pool: Arc<ArenaPool<f32>>,
) {
    let mut batcher = Batcher::new(policy, pool);
    loop {
        let wait = batcher
            .next_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(IntakeMsg::Req(req)) => {
                let now = Instant::now();
                if let Some(batch) = batcher.push(req, now) {
                    metrics.record_batch(batch.len(), policy.max_batch);
                    let _ = work_tx.send(WorkerMsg::Work(batch));
                }
                metrics.set_queue_depth(batcher.pending_requests());
            }
            Ok(IntakeMsg::Drain(ack)) => {
                for batch in batcher.flush_all() {
                    metrics.record_batch(batch.len(), policy.max_batch);
                    let _ = work_tx.send(WorkerMsg::Work(batch));
                }
                metrics.set_queue_depth(0);
                // One sync per worker: each worker answers once it has
                // finished everything queued before the sync.
                for _ in 0..workers {
                    let _ = work_tx.send(WorkerMsg::Sync(ack.clone()));
                }
            }
            Ok(IntakeMsg::Shutdown) => {
                for batch in batcher.flush_all() {
                    metrics.record_batch(batch.len(), policy.max_batch);
                    let _ = work_tx.send(WorkerMsg::Work(batch));
                }
                metrics.set_queue_depth(0);
                for _ in 0..workers {
                    let _ = work_tx.send(WorkerMsg::Stop);
                }
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                for batch in batcher.flush_expired(Instant::now()) {
                    metrics.record_batch(batch.len(), policy.max_batch);
                    let _ = work_tx.send(WorkerMsg::Work(batch));
                }
                metrics.set_queue_depth(batcher.pending_requests());
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for _ in 0..workers {
                    let _ = work_tx.send(WorkerMsg::Stop);
                }
                return;
            }
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<WorkerMsg>>>,
    recipe: ComputeRecipe,
    metrics: Arc<Metrics>,
    pool: Arc<ArenaPool<f32>>,
) {
    // Build the per-thread compute state; if that fails every batch is
    // answered with the error.  The Scratch pool lives as long as the
    // worker — after the first batch the compute path stops allocating.
    let ctx = ComputeCtx::new(&recipe);
    let mut scratch = Scratch::<f32>::new();
    loop {
        let msg = {
            // Poison recovery: a sibling worker that panicked while
            // receiving must not take the whole pool down with it.
            let guard = rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            guard.recv()
        };
        match msg {
            Ok(WorkerMsg::Work(mut batch)) => {
                let size = batch.len();
                let result = match &ctx {
                    Ok(ctx) => ctx.run_batch(&mut batch, &mut scratch),
                    Err(e) => Err(e.clone()),
                };
                let Batch { arena, meta, .. } = batch;
                match result {
                    Ok(()) => {
                        // Share the result arena across all responses
                        // (zero copies), then park it for recycling.
                        let shared = Arc::new(arena);
                        for (frame, m) in meta.into_iter().enumerate() {
                            metrics.completed.fetch_add(1, Ordering::Relaxed);
                            let latency = m.submitted.elapsed();
                            metrics.record_latency(latency);
                            let _ = m.reply.send(FftResponse::ok(
                                m.id,
                                shared.clone(),
                                frame,
                                size,
                                latency,
                            ));
                            drop(m.permit);
                        }
                        pool.recycle(shared);
                    }
                    Err(e) => {
                        for m in meta {
                            metrics.failed.fetch_add(1, Ordering::Relaxed);
                            let _ = m.reply.send(FftResponse::err(
                                m.id,
                                e.clone(),
                                size,
                                m.submitted.elapsed(),
                            ));
                            drop(m.permit);
                        }
                        pool.recycle(Arc::new(arena));
                    }
                }
            }
            Ok(WorkerMsg::Sync(ack)) => {
                let _ = ack.send(());
            }
            Ok(WorkerMsg::Stop) | Err(_) => return,
        }
    }
}
