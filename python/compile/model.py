"""Layer-2 JAX models: the compute graphs that get AOT-lowered to HLO.

Each factory returns a jittable function over split-format (B, n)
arrays.  All of them bottom out in the Layer-1 Pallas pass kernels, so
the paper's dual-select FMA butterfly is the compute hot-spot of every
artifact the Rust runtime serves.

Models
------
``make_fft``             forward or inverse FFT, any strategy
``make_matched_filter``  radar pulse compression: IFFT(FFT(x) * conj(H))
                         with the reference-chirp spectrum H baked in as
                         a constant (the paper's motivating radar
                         application)
``make_power_spectrum``  |FFT(x)|^2 — the spectrogram column primitive
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from compile.kernels import ref, stockham


def make_fft(n: int, strategy: str = "dual", inverse: bool = False):
    """(xre, xim) -> (yre, yim), shapes (B, n)."""

    def fn(xre, xim):
        return stockham.fft(xre, xim, strategy=strategy, inverse=inverse)

    fn.__name__ = f"fft_{'inv' if inverse else 'fwd'}_{strategy}_n{n}"
    return fn


def lfm_chirp(n: int, f0: float = 0.05, f1: float = 0.45) -> np.ndarray:
    """Unit-amplitude linear-FM chirp sweeping f0..f1 cycles/sample.

    The synthetic radar waveform used by the matched-filter model and
    the workload generators (paper's motivating application).  Matches
    ``signal::chirp`` on the Rust side.
    """
    t = np.arange(n, dtype=np.float64)
    phase = 2.0 * np.pi * (f0 * t + 0.5 * (f1 - f0) * t * t / n)
    return np.exp(1j * phase)


def make_matched_filter(n: int, strategy: str = "dual"):
    """Pulse compression against the baked-in LFM chirp spectrum."""
    h = lfm_chirp(n)
    hr64, hi64 = ref.stockham_fft(h.real[None, :], h.imag[None, :], "dual")

    def fn(xre, xim):
        dtype = xre.dtype
        hre = jnp.asarray(hr64, dtype)
        him = jnp.asarray(hi64, dtype)
        xr, xi = stockham.fft(xre, xim, strategy=strategy)
        # X * conj(H)
        yr = xr * hre + xi * him
        yi = xi * hre - xr * him
        return stockham.fft(yr, yi, strategy=strategy, inverse=True)

    fn.__name__ = f"matched_filter_{strategy}_n{n}"
    return fn


def make_power_spectrum(n: int, strategy: str = "dual"):
    """(xre, xim) -> (|X|^2,) — one STFT/spectrogram column."""

    def fn(xre, xim):
        xr, xi = stockham.fft(xre, xim, strategy=strategy)
        return (xr * xr + xi * xi,)

    fn.__name__ = f"power_spectrum_{strategy}_n{n}"
    return fn
