"""Full radix-2 Stockham FFT composed from the Layer-1 Pallas kernels.

Two composition modes:

* ``mode="per-pass"`` — one Pallas call per pass (log2 n calls).  The
  simplest mapping; each interpret-mode call lowers to its own HLO
  while-loop, which costs ~10x per-call overhead on the CPU PJRT
  runtime.
* ``mode="fused"`` (default) — the ENTIRE transform as ONE Pallas
  kernel: all log2(n) passes execute on values inside a single kernel
  invocation.  This is both the faster AOT artifact (one while-loop;
  §Perf L2 iteration in EXPERIMENTS.md) and the honest TPU design: the
  whole small FFT stays VMEM-resident across passes (DESIGN.md
  §Hardware-Adaptation).

Both modes use identical arithmetic (same 6-FMA butterfly, same table
values, same operation order), so they are numerically interchangeable;
pytest asserts it.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

from compile import twiddle
from compile.kernels import butterfly


def _fused_tables(n, m, strategy, sign, dtype):
    """Flat list of per-pass table arrays (Pallas kernel inputs)."""
    flat = []
    for p in range(m):
        angles = twiddle.pass_angles(n, p, sign)
        s = 1 << p
        if strategy == "standard":
            wr, wi = twiddle.plain_table(angles)
            flat += [jnp.asarray(np.reshape(z, (1, 1, s)), dtype) for z in (wr, wi)]
        else:
            flat += [
                jnp.asarray(np.reshape(z, (1, 1, s)), dtype)
                for z in twiddle.ratio_table(angles, strategy)
            ]
    return flat


def _fused_kernel(n, m, strategy):
    """Build the all-passes-in-one Pallas kernel body.

    Argument order: xr, xi, per-pass tables (2 or 4 refs per pass),
    then the two output refs.
    """
    per_pass = 2 if strategy == "standard" else 4

    def kernel(xr_ref, xi_ref, *refs):
        tab_refs = refs[: m * per_pass]
        yr_ref, yi_ref = refs[m * per_pass :]
        xr = xr_ref[...]  # (B, n)
        xi = xi_ref[...]
        b = xr.shape[0]
        for p in range(m):
            s = 1 << p
            l = n >> (p + 1)
            vr = xr.reshape(b, 2, l, s)
            vi = xi.reshape(b, 2, l, s)
            ar, br = vr[:, 0], vr[:, 1]
            ai, bi = vi[:, 0], vi[:, 1]
            tabs = [tab_refs[p * per_pass + i][...] for i in range(per_pass)]
            if strategy == "standard":
                wr, wi = tabs
                tr = wr * br - wi * bi
                ti = wi * br + wr * bi
                Ar, Ai, Br, Bi = ar + tr, ai + ti, ar - tr, ai - ti
            else:
                m1, m2, t, sel = tabs
                cosp = sel != 0.0
                u = jnp.where(cosp, br, bi)
                v = jnp.where(cosp, bi, br)
                s1 = u - t * v
                s2 = v + t * u
                p1 = m1 * s1
                p2 = m2 * s2
                Ar, Br, Ai, Bi = ar + p1, ar - p1, ai + p2, ai - p2
            xr = jnp.stack([Ar, Br], axis=2).reshape(b, n)
            xi = jnp.stack([Ai, Bi], axis=2).reshape(b, n)
        yr_ref[...] = xr
        yi_ref[...] = xi

    return kernel


def fft(xre, xim, *, strategy: str = "dual", inverse: bool = False, mode: str = "fused"):
    """Batched split-format FFT: (B, n) re/im -> (B, n) re/im."""
    n = xre.shape[-1]
    m = int(math.log2(n))
    if 1 << m != n:
        raise ValueError(f"n={n} must be a power of two")
    sign = 1.0 if inverse else -1.0

    if mode == "fused":
        b = xre.shape[0]
        kernel = _fused_kernel(n, m, strategy)
        tables = _fused_tables(n, m, strategy, sign, xre.dtype)
        out = jax.ShapeDtypeStruct((b, n), xre.dtype)
        xre, xim = pl.pallas_call(kernel, out_shape=(out, out), interpret=True)(
            xre, xim, *tables
        )
    elif mode == "per-pass":
        for p in range(m):
            xre, xim = butterfly.stockham_pass(
                xre, xim, n=n, p=p, strategy=strategy, inverse=inverse
            )
    else:
        raise ValueError(f"unknown mode {mode!r}")

    if inverse:
        scale = xre.dtype.type(1.0 / n)
        xre = xre * scale
        xim = xim * scale
    return xre, xim
