"""Layer-1 Pallas kernels: one Stockham radix-2 FFT pass per call.

Each kernel processes a full pass over a batch of split-format signals:

    inputs   x_re, x_im        (B, 2, l, s)   first/second half blocks
    tables   m1, m2, t, sel    (1, s)         per-pass ratio table
    outputs  y_re, y_im        (B, l, 2, s)   interleaved A/B outputs

The dual-select decision is *data-encoded* (the ``sel`` mask swaps the
operands with a ``jnp.where`` select, a free VPU op) so the kernel is
branch-free — this is the paper's "the per-twiddle branch can be
eliminated entirely by encoding the operand ordering into the
precomputed table entries", adapted for TPU/Pallas where warp-style
divergence does not exist (see DESIGN.md §Hardware-Adaptation).

The butterfly body is 6 multiply-adds per output point pair, exactly the
paper's proven-minimal FMA count; on TPU these map onto VPU fused
multiply-adds.  ``interpret=True`` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret-mode lowers to plain
HLO so the AOT artifacts run on the Rust PJRT CPU client.

VMEM sizing (TPU estimate, recorded in EXPERIMENTS.md): a pass block for
B=32, N=1024, f32 is 32*1024*2 arrays * 4 B * (in+out) = 1 MiB, far
under the ~16 MiB VMEM budget, so a whole pass is VMEM-resident and the
kernel is HBM-bandwidth-bound at 16 B/point per pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

from compile import twiddle


def _ratio_pass_kernel(xr_ref, xi_ref, m1_ref, m2_ref, t_ref, sel_ref, yr_ref, yi_ref):
    """Branch-free 6-FMA ratio butterfly over one pass block."""
    ar = xr_ref[:, 0]  # (B, l, s)
    br = xr_ref[:, 1]
    ai = xi_ref[:, 0]
    bi = xi_ref[:, 1]
    t = t_ref[...]  # (1, s) broadcasts over (B, l, s)
    m1 = m1_ref[...]
    m2 = m2_ref[...]
    cos_path = sel_ref[...] != 0.0

    # Operand swap is a select, not a branch.
    u = jnp.where(cos_path, br, bi)
    v = jnp.where(cos_path, bi, br)

    s1 = u - t * v  # FMA 1
    s2 = v + t * u  # FMA 2
    p1 = m1 * s1
    p2 = m2 * s2
    yr_ref[:, :, 0] = ar + p1  # FMA 3 (A_r)
    yr_ref[:, :, 1] = ar - p1  # FMA 4 (B_r)
    yi_ref[:, :, 0] = ai + p2  # FMA 5 (A_i)
    yi_ref[:, :, 1] = ai - p2  # FMA 6 (B_i)


def _standard_pass_kernel(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    """The 10-op schoolbook butterfly (paper eqs. 2-3) — baseline."""
    ar = xr_ref[:, 0]
    br = xr_ref[:, 1]
    ai = xi_ref[:, 0]
    bi = xi_ref[:, 1]
    wr = wr_ref[...]
    wi = wi_ref[...]

    tr = wr * br - wi * bi
    ti = wi * br + wr * bi
    yr_ref[:, :, 0] = ar + tr
    yr_ref[:, :, 1] = ar - tr
    yi_ref[:, :, 0] = ai + ti
    yi_ref[:, :, 1] = ai - ti


@functools.partial(jax.jit, static_argnames=("n", "p", "strategy", "inverse"))
def stockham_pass(xre, xim, *, n: int, p: int, strategy: str, inverse: bool = False):
    """Apply Stockham pass ``p`` of an ``n``-point FFT via a Pallas call.

    ``xre``/``xim`` have shape (B, n); returns same-shape arrays.
    """
    if strategy not in twiddle.STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")
    b = xre.shape[0]
    dtype = xre.dtype
    s = 1 << p
    l = n >> (p + 1)
    sign = 1.0 if inverse else -1.0

    xr = xre.reshape(b, 2, l, s)
    xi = xim.reshape(b, 2, l, s)
    angles = twiddle.pass_angles(n, p, sign)

    out_shape = (
        jax.ShapeDtypeStruct((b, l, 2, s), dtype),
        jax.ShapeDtypeStruct((b, l, 2, s), dtype),
    )

    if strategy == "standard":
        wr, wi = twiddle.plain_table(angles)
        tables = (
            jnp.asarray(wr.reshape(1, s), dtype),
            jnp.asarray(wi.reshape(1, s), dtype),
        )
        kernel = _standard_pass_kernel
    else:
        m1, m2, t, sel = twiddle.ratio_table(angles, strategy)
        tables = tuple(
            jnp.asarray(z.reshape(1, s), dtype) for z in (m1, m2, t, sel)
        )
        kernel = _ratio_pass_kernel

    yr, yi = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        interpret=True,
    )(xr, xi, *tables)
    return yr.reshape(b, n), yi.reshape(b, n)
