"""Pure-numpy correctness oracles for the Pallas kernels and JAX models.

Everything here is straight-line float64 numpy: the naive O(N^2) DFT
(ground truth), a reference Stockham driver that mirrors the exact pass
structure of the Pallas kernels, and reference implementations of each
butterfly factorization.  The pytest suite asserts the Pallas kernels
(float32/float16) match these oracles to precision-scaled tolerances.
"""

from __future__ import annotations

import numpy as np

from compile import twiddle


def naive_dft(x: np.ndarray, inverse: bool = False) -> np.ndarray:
    """O(N^2) complex128 DFT — the ground truth everything is judged by."""
    x = np.asarray(x, dtype=np.complex128)
    n = x.shape[-1]
    k = np.arange(n)
    sign = 2.0j * np.pi / n if inverse else -2.0j * np.pi / n
    mat = np.exp(sign * np.outer(k, k))
    y = x @ mat.T
    return y / n if inverse else y


def butterfly_standard(ar, ai, br, bi, wr, wi):
    """The 10-op schoolbook butterfly (paper eqs. 2-3)."""
    tr = wr * br - wi * bi
    ti = wi * br + wr * bi
    return ar + tr, ai + ti, ar - tr, ai - ti


def butterfly_ratio(ar, ai, br, bi, m1, m2, t, sel):
    """The branch-free 6-FMA ratio butterfly (see twiddle.py docstring).

    Covers Linzer-Feig, cosine, and dual-select — they differ only in the
    precomputed (m1, m2, t, sel) table.
    """
    u = np.where(sel != 0.0, br, bi)
    v = np.where(sel != 0.0, bi, br)
    s1 = u - t * v
    s2 = v + t * u
    return ar + m1 * s1, ai + m2 * s2, ar - m1 * s1, ai - m2 * s2


def stockham_pass(xre, xim, n, p, strategy, sign=-1.0):
    """One Stockham radix-2 pass over (..., n) split-format arrays.

    Mirrors the Pallas kernel exactly: view the first/second halves as
    (l, s) blocks, apply the butterfly, interleave into (l, 2, s).
    """
    l = n >> (p + 1)
    s = 1 << p
    lead = xre.shape[:-1]
    ar = xre[..., : n // 2].reshape(*lead, l, s)
    br = xre[..., n // 2 :].reshape(*lead, l, s)
    ai = xim[..., : n // 2].reshape(*lead, l, s)
    bi = xim[..., n // 2 :].reshape(*lead, l, s)

    # Twiddle varies along the stride axis j (shape (1, s)), shared
    # across the l groups.
    angles = twiddle.pass_angles(n, p, sign)
    if strategy == "standard":
        wr, wi = twiddle.plain_table(angles)
        wr = wr.reshape(1, s)
        wi = wi.reshape(1, s)
        Ar, Ai, Br, Bi = butterfly_standard(ar, ai, br, bi, wr, wi)
    else:
        m1, m2, t, sel = twiddle.ratio_table(angles, strategy)
        m1, m2, t, sel = (z.reshape(1, s) for z in (m1, m2, t, sel))
        Ar, Ai, Br, Bi = butterfly_ratio(ar, ai, br, bi, m1, m2, t, sel)

    yre = np.stack([Ar, Br], axis=-2).reshape(*lead, n)
    yim = np.stack([Ai, Bi], axis=-2).reshape(*lead, n)
    return yre, yim


def stockham_fft(xre, xim, strategy="dual", inverse=False):
    """Full radix-2 Stockham FFT over split-format (..., n) arrays."""
    xre = np.asarray(xre, dtype=np.float64)
    xim = np.asarray(xim, dtype=np.float64)
    n = xre.shape[-1]
    m = int(np.log2(n))
    assert 1 << m == n, f"n={n} must be a power of two"
    sign = 1.0 if inverse else -1.0
    for p in range(m):
        xre, xim = stockham_pass(xre, xim, n, p, strategy, sign)
    if inverse:
        xre = xre / n
        xim = xim / n
    return xre, xim


def matched_filter(xre, xim, hre, him):
    """Frequency-domain matched filter: IFFT( FFT(x) * conj(H) ).

    ``(hre, him)`` is the *spectrum* of the reference pulse.  This is the
    radar pulse-compression pipeline the paper motivates.
    """
    Xr, Xi = stockham_fft(xre, xim, "dual")
    # X * conj(H)
    Yr = Xr * hre + Xi * him
    Yi = Xi * hre - Xr * him
    return stockham_fft(Yr, Yi, "dual", inverse=True)
