"""Twiddle-table construction for the FMA butterfly factorizations.

This is the build-time (numpy, float64) implementation of the paper's
Algorithm 1 plus the two baseline tables it compares against.  The same
logic is re-implemented in Rust (``rust/src/fft/twiddle.rs``) for the
native path; the pytest suite cross-checks the two through the AOT
artifacts.

Conventions
-----------
A radix-2 Stockham pass ``p`` (0-based) on an ``n``-point transform
views the half-arrays as ``(l, s)`` blocks with ``s = 1 << p`` and
``l = n >> (p+1)``, and has ``s`` distinct twiddle factors ``W^{j*l}``
for ``j in [0, s)`` (the twiddle varies along the stride axis and is
shared across the ``l`` groups); the twiddle angle is
``theta = sign * 2*pi*j*l/n`` with ``sign = -1`` for the forward
transform and ``+1`` for the inverse.  Pass 0 therefore has the single
twiddle W^0 = 1 — exactly the Linzer-Feig singularity — and the last
pass has all of ``W^j, j in [0, n/2)``.

Table entry layout (the paper's Algorithm 1, extended so the butterfly
kernel is *branch-free*):

``m1``   signed outer multiplier for the ``s1`` lane (``sigma * mult``)
``m2``   outer multiplier for the ``s2`` lane (``mult``)
``t``    the bounded precomputed ratio (``tan`` or ``cot``)
``sel``  1.0 when the cosine path was selected, 0.0 for the sine path

With ``u = sel ? br : bi`` and ``v = sel ? bi : br`` the butterfly is

    s1 = u - t*v          (FMA)
    s2 = v + t*u          (FMA)
    Ar = ar + m1*s1       (FMA)      Br = ar - m1*s1   (FMA)
    Ai = ai + m2*s2       (FMA)      Bi = ai - m2*s2   (FMA)

six FMAs regardless of path, exactly as the paper requires, and the
select is a data movement, not a branch.

NOTE on the paper's eq. (4): as printed, ``s2 = (wr/wi)*br + bi`` does
not reproduce ``Ai = ai + wi*br + wr*bi``; the algebraically correct
sine-path factorization is ``s2 = br + (wr/wi)*bi``.  We implement the
correct form (the cosine-path eq. (7) is correct as printed and the two
are mirror images).
"""

from __future__ import annotations

import numpy as np

# The epsilon used by "standard practice" clamping for the singular
# baseline tables (the paper quotes 1e-7).
CLAMP_EPS = 1e-7

STRATEGIES = ("standard", "lf", "cos", "dual")


def pass_angles(n: int, p: int, sign: float = -1.0) -> np.ndarray:
    """Twiddle angles for Stockham pass ``p`` of an ``n``-point FFT."""
    if n & (n - 1) or n < 2:
        raise ValueError(f"n must be a power of two >= 2, got {n}")
    s = 1 << p
    l = n >> (p + 1)
    if l < 1:
        raise ValueError(f"pass {p} out of range for n={n}")
    j = np.arange(s, dtype=np.float64)
    return sign * 2.0 * np.pi * j * l / n


def plain_table(angles: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(wr, wi) pairs — the 10-op standard butterfly table."""
    return np.cos(angles), np.sin(angles)


def _select_masks(wr: np.ndarray, wi: np.ndarray, mode: str) -> np.ndarray:
    """Boolean mask: True where the *cosine* path is used."""
    if mode == "dual":
        return np.abs(wr) >= np.abs(wi)
    if mode == "lf":  # Linzer-Feig: always the sine path
        return np.zeros_like(wr, dtype=bool)
    if mode == "cos":  # cosine factorization: always the cosine path
        return np.ones_like(wr, dtype=bool)
    raise ValueError(f"unknown ratio strategy {mode!r}")


def ratio_table(
    angles: np.ndarray, mode: str, clamp: bool = True
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Build (m1, m2, t, sel) for one pass.

    ``mode`` is one of ``lf`` / ``cos`` / ``dual``.  For the two singular
    baselines the denominator is clamped to ``CLAMP_EPS`` (standard
    practice, what the paper criticizes) unless ``clamp=False`` in which
    case the ratio may be inf.  Dual-select never needs clamping.
    """
    wr = np.cos(angles)
    wi = np.sin(angles)
    cos_path = _select_masks(wr, wi, mode)

    # Denominator = the selected outer multiplier.
    mult = np.where(cos_path, wr, wi)
    if mode != "dual" and clamp:
        tiny = np.abs(mult) < CLAMP_EPS
        mult = np.where(tiny, np.where(mult < 0, -CLAMP_EPS, CLAMP_EPS), mult)
    num = np.where(cos_path, wi, wr)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = num / mult

    sigma = np.where(cos_path, 1.0, -1.0)
    m1 = sigma * mult
    m2 = mult
    sel = cos_path.astype(np.float64)
    return m1, m2, t, sel


def dual_select_table(n: int, sign: float = -1.0):
    """The paper's Algorithm 1 over the *flat* twiddle index k in [0, n/2).

    Returns (mult, ratio, sel) exactly as the paper stores them — used by
    the analysis/audit tests; the per-pass kernels use ``ratio_table``.
    """
    k = np.arange(n // 2, dtype=np.float64)
    theta = sign * 2.0 * np.pi * k / n
    wr, wi = np.cos(theta), np.sin(theta)
    cos_path = np.abs(wr) >= np.abs(wi)
    mult = np.where(cos_path, wr, wi)
    ratio = np.where(cos_path, wi, wr) / mult
    return mult, ratio, cos_path


def max_ratio(n: int, mode: str, clamp: bool = True) -> float:
    """|t|_max over all passes of an n-point transform (Table I column)."""
    worst = 0.0
    m = int(np.log2(n))
    for p in range(m):
        _, _, t, _ = ratio_table(pass_angles(n, p), mode, clamp=clamp)
        worst = max(worst, float(np.max(np.abs(t))))
    return worst


def ratio_stats(n: int, mode: str) -> dict:
    """Paper-style Table I statistics over the flat twiddle table.

    ``max_nonsingular`` is |t|_max over entries whose outer multiplier is
    not (near-)zero — this matches the paper's reported 163.0 for
    Linzer-Feig at N=1024 (at k=1; the exactly-singular k=0 entry is
    counted in ``singular`` instead).  ``near_singular`` counts entries
    where the multiplier is nonzero but below 1e-9 (the cosine path's
    k=N/4 entry, cos(pi/2) ~ 6e-17, the paper's "0*" footnote).
    """
    k = np.arange(n // 2, dtype=np.float64)
    theta = -2.0 * np.pi * k / n
    wr, wi = np.cos(theta), np.sin(theta)
    cos_path = _select_masks(wr, wi, mode)
    mult = np.where(cos_path, wr, wi)
    num = np.where(cos_path, wi, wr)
    singular = mult == 0.0
    near = (~singular) & (np.abs(mult) < 1e-9)
    ok = ~(singular | near)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = np.abs(num / mult)
    tmax = float(np.max(t[ok]))
    argmax = int(k[ok][np.argmax(t[ok])])
    return {
        "max_nonsingular": tmax,
        "argmax_k": argmax,
        "singular": int(np.sum(singular)),
        "near_singular": int(np.sum(near)),
        "max_clamped": float(np.max(np.abs(t[ok | near]))) if near.any() else tmax,
        "cos_path_count": int(np.sum(cos_path)),
        "sin_path_count": int(np.sum(~cos_path)),
    }
