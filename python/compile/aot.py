"""AOT lowering: JAX models -> HLO text artifacts + manifest.

The interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  Lowered with
``return_tuple=True`` so the Rust side unwraps a tuple literal.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target).  Python never runs again after this: the Rust
coordinator loads the artifacts via PJRT and serves them.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as model_lib


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    CRITICAL: ``print_large_constants=True``.  The default HLO printer
    *elides* constants with >= 16 elements as ``constant({...})`` — the
    twiddle tables! — and the old text parser silently materializes
    garbage for them, producing numerically wrong (not crashing)
    executables.  Symptom when missed: every Stockham pass with
    stride >= 16 no-ops and an n-point FFT degrades into 16-point
    comb spectra.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "constant({...})" in text:
        raise RuntimeError("HLO text contains elided constants — unrunnable")
    return text


# The default artifact set served by the coordinator.  Kept moderate so
# `make artifacts` stays fast; `--full` adds the sweep set used by the
# e2e benches.
DEFAULT_VARIANTS = [
    # (kind, n, batch, strategy, inverse)
    ("fft", 1024, 1, "dual", False),
    ("fft", 1024, 1, "dual", True),
    ("fft", 1024, 32, "dual", False),
    ("fft", 1024, 32, "dual", True),
    ("fft", 1024, 1, "lf", False),
    ("fft", 1024, 32, "lf", False),
    ("fft", 256, 1, "dual", False),
    ("fft", 256, 32, "dual", False),
    ("matched_filter", 1024, 1, "dual", False),
    ("matched_filter", 1024, 32, "dual", False),
    ("power_spectrum", 256, 32, "dual", False),
]

FULL_EXTRA = [
    ("fft", 256, 1, "lf", False),
    ("fft", 256, 1, "standard", False),
    ("fft", 1024, 1, "standard", False),
    ("fft", 1024, 8, "dual", False),
    ("fft", 4096, 1, "dual", False),
    ("fft", 4096, 8, "dual", False),
    ("matched_filter", 1024, 8, "dual", False),
]


def variant_name(kind, n, batch, strategy, inverse, dtype="f32"):
    direction = "inv" if inverse else "fwd"
    return f"{kind}_{direction}_{strategy}_n{n}_b{batch}_{dtype}"


def build_fn(kind, n, strategy, inverse):
    if kind == "fft":
        return model_lib.make_fft(n, strategy, inverse)
    if kind == "matched_filter":
        return model_lib.make_matched_filter(n, strategy)
    if kind == "power_spectrum":
        return model_lib.make_power_spectrum(n, strategy)
    raise ValueError(f"unknown kind {kind!r}")


def lower_variant(kind, n, batch, strategy, inverse, dtype=jnp.float32):
    fn = build_fn(kind, n, strategy, inverse)
    spec = jax.ShapeDtypeStruct((batch, n), dtype)
    lowered = jax.jit(fn).lower(spec, spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="also lower the sweep set")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    variants = list(DEFAULT_VARIANTS) + (FULL_EXTRA if args.full else [])

    manifest = {"format": "hlo-text", "version": 1, "artifacts": []}
    for kind, n, batch, strategy, inverse in variants:
        name = variant_name(kind, n, batch, strategy, inverse)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_variant(kind, n, batch, strategy, inverse)
        with open(path, "w") as f:
            f.write(text)
        n_outputs = 1 if kind == "power_spectrum" else 2
        manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": kind,
                "n": n,
                "batch": batch,
                "strategy": strategy,
                "inverse": inverse,
                "dtype": "f32",
                "inputs": [[batch, n], [batch, n]],
                "outputs": [[batch, n]] * n_outputs,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
