"""Layer-2 model graphs and the AOT lowering pipeline."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model as model_lib
from compile.kernels import ref


RNG = np.random.default_rng(99)


def rel_l2(got, want):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    return np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-300)


class TestModels:
    @pytest.mark.parametrize("n", [256, 1024])
    def test_fft_model_matches_numpy(self, n):
        fn = model_lib.make_fft(n, "dual")
        xr = RNG.standard_normal((2, n)).astype(np.float32)
        xi = RNG.standard_normal((2, n)).astype(np.float32)
        yr, yi = jax.jit(fn)(jnp.asarray(xr), jnp.asarray(xi))
        want = np.fft.fft(xr.astype(np.float64) + 1j * xi.astype(np.float64), axis=-1)
        assert rel_l2(np.asarray(yr) + 1j * np.asarray(yi), want) < 1e-5

    def test_inverse_model(self):
        n = 256
        fwd = jax.jit(model_lib.make_fft(n, "dual", inverse=False))
        inv = jax.jit(model_lib.make_fft(n, "dual", inverse=True))
        xr = RNG.standard_normal((1, n)).astype(np.float32)
        xi = RNG.standard_normal((1, n)).astype(np.float32)
        yr, yi = inv(*fwd(jnp.asarray(xr), jnp.asarray(xi)))
        assert rel_l2(np.asarray(yr), xr) < 1e-5
        assert rel_l2(np.asarray(yi), xi) < 1e-5

    def test_matched_filter_model_vs_oracle(self):
        n = 512
        fn = jax.jit(model_lib.make_matched_filter(n, "dual"))
        xr = RNG.standard_normal((2, n)).astype(np.float32)
        xi = RNG.standard_normal((2, n)).astype(np.float32)
        yr, yi = fn(jnp.asarray(xr), jnp.asarray(xi))

        h = model_lib.lfm_chirp(n)
        hr, hi = ref.stockham_fft(h.real[None], h.imag[None], "dual")
        wr, wi = ref.matched_filter(
            xr.astype(np.float64), xi.astype(np.float64), hr, hi
        )
        assert rel_l2(np.asarray(yr) + 1j * np.asarray(yi), wr + 1j * wi) < 1e-4

    def test_power_spectrum_model(self):
        n = 256
        fn = jax.jit(model_lib.make_power_spectrum(n, "dual"))
        xr = RNG.standard_normal((1, n)).astype(np.float32)
        xi = np.zeros_like(xr)
        (ps,) = fn(jnp.asarray(xr), jnp.asarray(xi))
        want = np.abs(np.fft.fft(xr.astype(np.float64), axis=-1)) ** 2
        assert rel_l2(ps, want) < 1e-4

    def test_chirp_is_unit_amplitude(self):
        c = model_lib.lfm_chirp(1024)
        np.testing.assert_allclose(np.abs(c), 1.0, atol=1e-12)


class TestAotLowering:
    def test_hlo_text_emitted(self):
        text = aot.lower_variant("fft", 64, 2, "dual", False)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_hlo_text_has_no_custom_calls(self):
        """interpret=True must lower to plain HLO the CPU client can run."""
        text = aot.lower_variant("fft", 64, 1, "dual", False)
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()

    def test_variant_name_stable(self):
        assert (
            aot.variant_name("fft", 1024, 32, "dual", False)
            == "fft_fwd_dual_n1024_b32_f32"
        )

    def test_manifest_on_disk_if_built(self):
        """If `make artifacts` ran, the manifest must describe real files."""
        art = os.path.join(os.path.dirname(__file__), "../../artifacts")
        mpath = os.path.join(art, "manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("artifacts not built")
        with open(mpath) as f:
            manifest = json.load(f)
        assert manifest["format"] == "hlo-text"
        for a in manifest["artifacts"]:
            path = os.path.join(art, a["file"])
            assert os.path.exists(path), a["file"]
            assert a["inputs"] == [[a["batch"], a["n"]]] * 2
