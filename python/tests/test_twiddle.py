"""Unit tests for the dual-select twiddle tables (paper Algorithm 1)."""

import numpy as np
import pytest

from compile import twiddle

SIZES = [2, 4, 8, 16, 64, 256, 1024, 4096]


class TestDualSelectBound:
    """Theorem 1: |t| <= 1 for every twiddle factor, any N."""

    @pytest.mark.parametrize("n", SIZES)
    def test_flat_table_bounded(self, n):
        _, ratio, _ = twiddle.dual_select_table(n)
        assert np.all(np.abs(ratio) <= 1.0 + 1e-15)

    @pytest.mark.parametrize("n", SIZES)
    def test_per_pass_tables_bounded(self, n):
        m = int(np.log2(n))
        for p in range(m):
            _, _, t, _ = twiddle.ratio_table(twiddle.pass_angles(n, p), "dual")
            assert np.all(np.abs(t) <= 1.0 + 1e-15), f"pass {p}"

    @pytest.mark.parametrize("n", SIZES)
    def test_multiplier_at_least_invsqrt2(self, n):
        """The selected outer multiplier is max(|cos|,|sin|) >= 1/sqrt(2)."""
        mult, _, _ = twiddle.dual_select_table(n)
        assert np.all(np.abs(mult) >= 1.0 / np.sqrt(2.0) - 1e-15)

    def test_max_ratio_exactly_one_at_n_over_8(self):
        """Paper SS V: dual-select max is 1.0, attained at k = N/8."""
        _, ratio, _ = twiddle.dual_select_table(1024)
        k = int(np.argmax(np.abs(ratio)))
        assert k == 1024 // 8
        assert abs(np.abs(ratio[k]) - 1.0) < 1e-12


class TestPaperConstants:
    """The exact Table I numbers for N=1024."""

    def test_lf_max_ratio_163(self):
        st = twiddle.ratio_stats(1024, "lf")
        assert st["max_nonsingular"] == pytest.approx(163.0, abs=0.05)
        assert st["argmax_k"] == 1  # smallest nonzero angle
        assert st["singular"] == 1  # W^0

    def test_cos_near_singular(self):
        st = twiddle.ratio_stats(1024, "cos")
        assert st["singular"] == 0  # cos(pi/2) is not exactly 0 in f64
        assert st["near_singular"] == 1  # the paper's "0*" footnote
        assert st["max_clamped"] > 1e16

    def test_dual_no_singularities(self):
        st = twiddle.ratio_stats(1024, "dual")
        assert st["singular"] == 0
        assert st["near_singular"] == 0
        assert st["max_nonsingular"] == pytest.approx(1.0, abs=1e-12)

    def test_path_split_50_50(self):
        """Paper SS V: exactly 256/256 for N=1024."""
        st = twiddle.ratio_stats(1024, "dual")
        assert st["cos_path_count"] == 256
        assert st["sin_path_count"] == 256

    @pytest.mark.parametrize("n", [8, 16, 64, 256, 1024, 4096])
    def test_path_split_even_when_divisible_by_8(self, n):
        st = twiddle.ratio_stats(n, "dual")
        assert st["cos_path_count"] == st["sin_path_count"] == n // 4


class TestClamping:
    def test_lf_clamp_bounds_table(self):
        m1, m2, t, sel = twiddle.ratio_table(
            twiddle.pass_angles(1024, 0), "lf", clamp=True
        )
        assert np.all(np.isfinite(t))
        assert np.max(np.abs(t)) == pytest.approx(1.0 / twiddle.CLAMP_EPS)

    def test_lf_unclamped_is_singular(self):
        _, _, t, _ = twiddle.ratio_table(
            twiddle.pass_angles(1024, 0), "lf", clamp=False
        )
        assert not np.all(np.isfinite(t))

    def test_dual_never_needs_clamp(self):
        for p in range(10):
            a = twiddle.pass_angles(1024, p)
            unclamped = twiddle.ratio_table(a, "dual", clamp=False)
            clamped = twiddle.ratio_table(a, "dual", clamp=True)
            for u, c in zip(unclamped, clamped):
                np.testing.assert_array_equal(u, c)


class TestTableStructure:
    @pytest.mark.parametrize("n", SIZES)
    def test_pass_angle_union_covers_flat_table(self, n):
        """Union of per-pass twiddles == the flat k in [0, n/2) table."""
        m = int(np.log2(n))
        seen = set()
        for p in range(m):
            l = n >> (p + 1)
            for j in range(1 << p):
                seen.add(j * l)
        assert seen == set(range(n // 2))

    def test_sign_flag_encodable(self):
        """m1 = sigma*mult, m2 = mult: sigma recoverable from m1/m2."""
        for p in range(10):
            m1, m2, _, sel = twiddle.ratio_table(twiddle.pass_angles(1024, p), "dual")
            sigma = np.where(sel != 0.0, 1.0, -1.0)
            np.testing.assert_allclose(m1, sigma * m2, rtol=0, atol=0)

    def test_inverse_angles_conjugate(self):
        fwd = twiddle.pass_angles(1024, 3, -1.0)
        inv = twiddle.pass_angles(1024, 3, +1.0)
        np.testing.assert_allclose(fwd, -inv)
