"""Hypothesis sweeps over the Pallas kernel's shapes and dtypes.

Property-based coverage of the L1 kernels: any power-of-two size, any
batch, any strategy, f32/f16 — always allclose to the float64 oracle at
a precision-scaled tolerance.
"""

import numpy as np

from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import twiddle
from compile.kernels import butterfly, ref, stockham


def rel_l2(got_r, got_i, want_r, want_i):
    got = np.asarray(got_r, np.float64) + 1j * np.asarray(got_i, np.float64)
    want = np.asarray(want_r, np.float64) + 1j * np.asarray(want_i, np.float64)
    return np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-300)


sizes = st.sampled_from([2, 4, 8, 16, 32, 64, 128, 256])
batches = st.integers(min_value=1, max_value=4)
strategies_st = st.sampled_from(twiddle.STRATEGIES)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(n=sizes, b=batches, strategy=strategies_st, seed=seeds)
def test_fft_matches_oracle_any_shape(n, b, strategy, seed):
    rng = np.random.default_rng(seed)
    xr = rng.standard_normal((b, n)).astype(np.float32)
    xi = rng.standard_normal((b, n)).astype(np.float32)
    got_r, got_i = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy=strategy)
    want = np.fft.fft(xr.astype(np.float64) + 1j * xi.astype(np.float64), axis=-1)
    tol = 5e-3 if strategy in ("lf", "cos") else 1e-4
    assert rel_l2(got_r, got_i, want.real, want.imag) < tol


@settings(max_examples=20, deadline=None)
@given(n=sizes, b=batches, seed=seeds)
def test_roundtrip_any_shape(n, b, seed):
    rng = np.random.default_rng(seed)
    xr = rng.standard_normal((b, n)).astype(np.float32)
    xi = rng.standard_normal((b, n)).astype(np.float32)
    fr, fi = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy="dual")
    gr, gi = stockham.fft(fr, fi, strategy="dual", inverse=True)
    assert rel_l2(gr, gi, xr, xi) < 1e-4


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([16, 64, 256]),
    p=st.integers(min_value=0, max_value=3),
    strategy=strategies_st,
    seed=seeds,
)
def test_single_pass_matches_oracle(n, p, strategy, seed):
    rng = np.random.default_rng(seed)
    xr = rng.standard_normal((2, n)).astype(np.float32)
    xi = rng.standard_normal((2, n)).astype(np.float32)
    got_r, got_i = butterfly.stockham_pass(
        jnp.asarray(xr), jnp.asarray(xi), n=n, p=p, strategy=strategy
    )
    want_r, want_i = ref.stockham_pass(
        xr.astype(np.float64), xi.astype(np.float64), n, p, strategy
    )
    assert rel_l2(got_r, got_i, want_r, want_i) < 1e-5


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 32, 128]), seed=seeds)
def test_fp16_dual_select_stays_accurate(n, seed):
    """Theorem 1 consequence: fp16 dual-select error stays ~m*eps."""
    rng = np.random.default_rng(seed)
    xr = rng.standard_normal((1, n)).astype(np.float16)
    xi = rng.standard_normal((1, n)).astype(np.float16)
    got_r, got_i = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy="dual")
    want = np.fft.fft(xr.astype(np.float64) + 1j * xi.astype(np.float64), axis=-1)
    m = int(np.log2(n))
    # generous: a few x m * eps_fp16
    assert rel_l2(got_r, got_i, want.real, want.imag) < 20 * m * 4.88e-4


@settings(max_examples=30, deadline=None)
@given(n=st.sampled_from([2, 4, 8, 16, 64, 256, 1024, 4096]))
def test_dual_select_bound_any_size(n):
    """Theorem 1 itself, swept over sizes."""
    _, ratio, _ = twiddle.dual_select_table(n)
    assert np.all(np.abs(ratio) <= 1.0 + 1e-15)
