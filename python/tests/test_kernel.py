"""Pallas kernel vs pure-numpy oracle — the core L1 correctness signal."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import twiddle
from compile.kernels import butterfly, ref, stockham

RNG = np.random.default_rng(1234)


def rand_split(b, n, dtype=np.float32):
    return (
        RNG.standard_normal((b, n)).astype(dtype),
        RNG.standard_normal((b, n)).astype(dtype),
    )


def rel_l2(got_r, got_i, want_r, want_i):
    got = np.asarray(got_r, np.float64) + 1j * np.asarray(got_i, np.float64)
    want = np.asarray(want_r, np.float64) + 1j * np.asarray(want_i, np.float64)
    return np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-300)


class TestSinglePass:
    """Each Stockham pass kernel matches the numpy pass oracle exactly."""

    @pytest.mark.parametrize("strategy", twiddle.STRATEGIES)
    @pytest.mark.parametrize("n,p", [(8, 0), (8, 1), (8, 2), (256, 0), (256, 4), (256, 7)])
    def test_pass_matches_ref(self, strategy, n, p):
        xr, xi = rand_split(3, n)
        got_r, got_i = butterfly.stockham_pass(
            jnp.asarray(xr), jnp.asarray(xi), n=n, p=p, strategy=strategy
        )
        want_r, want_i = ref.stockham_pass(
            xr.astype(np.float64), xi.astype(np.float64), n, p, strategy
        )
        assert rel_l2(got_r, got_i, want_r, want_i) < 1e-6

    @pytest.mark.parametrize("n,p", [(64, 0), (64, 3), (64, 5)])
    def test_inverse_pass(self, n, p):
        xr, xi = rand_split(2, n)
        got_r, got_i = butterfly.stockham_pass(
            jnp.asarray(xr), jnp.asarray(xi), n=n, p=p, strategy="dual", inverse=True
        )
        want_r, want_i = ref.stockham_pass(
            xr.astype(np.float64), xi.astype(np.float64), n, p, "dual", sign=+1.0
        )
        assert rel_l2(got_r, got_i, want_r, want_i) < 1e-6


class TestFullFFT:
    @pytest.mark.parametrize("strategy", twiddle.STRATEGIES)
    @pytest.mark.parametrize("n", [2, 4, 16, 256, 1024])
    def test_forward_vs_numpy_fft(self, strategy, n):
        xr, xi = rand_split(2, n)
        got_r, got_i = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy=strategy)
        want = np.fft.fft(xr.astype(np.float64) + 1j * xi.astype(np.float64), axis=-1)
        tol = 5e-3 if strategy in ("lf", "cos") else 5e-5  # clamped baselines degrade
        assert rel_l2(got_r, got_i, want.real, want.imag) < tol

    @pytest.mark.parametrize("n", [4, 64, 1024])
    def test_roundtrip_identity(self, n):
        xr, xi = rand_split(2, n)
        fr, fi = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy="dual")
        gr, gi = stockham.fft(fr, fi, strategy="dual", inverse=True)
        assert rel_l2(gr, gi, xr, xi) < 1e-5

    def test_impulse_is_flat(self):
        n = 64
        xr = np.zeros((1, n), np.float32)
        xr[0, 0] = 1.0
        xi = np.zeros_like(xr)
        fr, fi = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy="dual")
        np.testing.assert_allclose(np.asarray(fr), 1.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fi), 0.0, atol=1e-5)

    def test_linearity(self):
        n = 128
        ar, ai = rand_split(1, n)
        br, bi = rand_split(1, n)
        f = lambda r, i: stockham.fft(jnp.asarray(r), jnp.asarray(i), strategy="dual")
        sr, si = f(ar + br, ai + bi)
        fr1, fi1 = f(ar, ai)
        fr2, fi2 = f(br, bi)
        assert rel_l2(sr, si, np.asarray(fr1) + np.asarray(fr2),
                      np.asarray(fi1) + np.asarray(fi2)) < 1e-5

    def test_parseval(self):
        n = 256
        xr, xi = rand_split(1, n)
        fr, fi = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy="dual")
        time_e = np.sum(xr.astype(np.float64) ** 2 + xi.astype(np.float64) ** 2)
        freq_e = np.sum(np.asarray(fr, np.float64) ** 2 + np.asarray(fi, np.float64) ** 2) / n
        assert abs(time_e - freq_e) / time_e < 1e-5


class TestFusedMode:
    """The fused all-passes-in-one-kernel AOT path is bit-identical to
    the per-pass composition (EXPERIMENTS.md §Perf L2)."""

    @pytest.mark.parametrize("strategy", twiddle.STRATEGIES)
    @pytest.mark.parametrize("n", [4, 64, 1024])
    def test_fused_bit_identical_to_per_pass(self, strategy, n):
        xr, xi = rand_split(2, n)
        a = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy=strategy, mode="fused")
        b = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy=strategy, mode="per-pass")
        assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
        assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))

    def test_fused_inverse_bit_identical(self):
        n = 256
        xr, xi = rand_split(1, n)
        a = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), inverse=True, mode="fused")
        b = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), inverse=True, mode="per-pass")
        assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))

    def test_unknown_mode_rejected(self):
        xr, xi = rand_split(1, 8)
        with pytest.raises(ValueError):
            stockham.fft(jnp.asarray(xr), jnp.asarray(xi), mode="bogus")


class TestPrecisionStory:
    """FP32: all strategies equivalent (paper SSV 'FP32 precision')."""

    def test_fp32_equivalence(self):
        n = 1024
        xr, xi = rand_split(4, n)
        want = np.fft.fft(xr.astype(np.float64) + 1j * xi.astype(np.float64), axis=-1)
        errs = {}
        for strategy in ("dual", "standard"):
            fr, fi = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy=strategy)
            errs[strategy] = rel_l2(fr, fi, want.real, want.imag)
        # Both ~1e-7, within 10x of each other.
        assert errs["dual"] < 1e-6
        assert errs["standard"] < 1e-6

    def test_fp16_dual_beats_lf(self):
        """In half precision the dual-select table wins (paper SS V).

        The clamped LF table contains |t| up to 1e7 whose products
        overflow/amplify in fp16; dual-select stays finite and accurate.
        """
        n = 1024
        xr, xi = (z.astype(np.float16) for z in rand_split(2, n))
        want = np.fft.fft(xr.astype(np.float64) + 1j * xi.astype(np.float64), axis=-1)
        fr, fi = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy="dual")
        err_dual = rel_l2(fr, fi, want.real, want.imag)
        fr, fi = stockham.fft(jnp.asarray(xr), jnp.asarray(xi), strategy="lf")
        err_lf = rel_l2(fr, fi, want.real, want.imag)
        assert err_dual < 5e-2
        # The clamped LF ratio (1e7) overflows fp16 entirely: the result
        # is NaN/inf — the paper's "rendering the FFT result meaningless".
        assert np.isnan(err_lf) or err_lf > 10 * err_dual


class TestMatchedFilterOracle:
    def test_matched_filter_peaks_at_target_delay(self):
        """Pulse compression concentrates energy at the pulse delay."""
        from compile import model as model_lib

        n = 1024
        chirp = model_lib.lfm_chirp(256)
        delay = 300
        x = np.zeros(n, dtype=complex)
        x[delay : delay + 256] = chirp
        hr = np.zeros((1, n)); hi = np.zeros((1, n))
        full = np.zeros(n, dtype=complex)
        full[:256] = chirp
        Hr, Hi = ref.stockham_fft(full.real[None], full.imag[None], "dual")
        yr, yi = ref.matched_filter(x.real[None], x.imag[None], Hr, Hi)
        mag = np.abs(yr + 1j * yi)[0]
        assert int(np.argmax(mag)) == delay
